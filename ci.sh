#!/usr/bin/env bash
# CI entry point: build, test, and a perf smoke so selection-pipeline
# regressions fail loudly.
#
#   ./ci.sh          tier-1 (build + tests) + quick bench smokes
#   ./ci.sh --bench  also run the unabridged selection bench
#
# The selection bench writes rust/BENCH_selection.json (median ns per
# Fig-8 point plus speedup vs the retained reference greedy) and exits
# non-zero if the arena-based solver's chosen sets diverge from the
# reference. The endtoend bench writes rust/BENCH_endtoend.json (ns per
# idle/round sim step, ring footprint) and exits non-zero if the
# incrementally-advanced forecast ring diverges from fresh-built windows.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== selection bench smoke (--quick) =="
cargo bench --bench selection -- --quick

echo "== endtoend bench smoke (--quick, ring divergence gate) =="
cargo bench --bench endtoend -- --quick

if [[ "${1:-}" == "--bench" ]]; then
    echo "== selection bench (default points) =="
    cargo bench --bench selection
fi

echo "CI OK"
