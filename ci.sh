#!/usr/bin/env bash
# CI entry point: build, test, and a perf smoke so selection-pipeline
# regressions fail loudly.
#
#   ./ci.sh          tier-1 (build + tests) + quick bench smokes
#   ./ci.sh --quick  tier-1 + the campaign, chaos, tree, steal and
#                    journal smokes (fastest gates: report-schema
#                    validation, worker-count determinism, the
#                    builtin-spec-vs-legacy Scenario::Global diff, the
#                    seeded fault-injection determinism/visibility gates,
#                    the 1M-client hierarchical-aggregation flat-vs-tree
#                    bitwise gate, the work-stealing B&B drain gate
#                    (Serial/Chunked/Steal × 1/2/8 pinned workers must
#                    agree bitwise), and the crash-resume gate (a run
#                    killed by a chaos crash and resumed from its
#                    journal + snapshot must be bit-identical to an
#                    uninterrupted run, and a durable campaign resume
#                    byte-identical at 1/2/8 workers), plus the telemetry
#                    determinism gate (the same scenario with the obs
#                    layer off vs fully armed must leave metrics, journal
#                    and campaign-report bytes identical, and the
#                    exported trace.json / TELEMETRY.json must be
#                    well-formed) — exit 1 on any divergence)
#   ./ci.sh --bench  also run the unabridged selection bench
#   ./ci.sh --arm    default run, then copy every fresh BENCH_*.json
#                    over its .baseline.json (commit them afterwards)
#
# The selection bench writes rust/BENCH_selection.json (median ns per
# Fig-8 point plus speedup vs the retained reference greedy, and the
# skewed-tree B&B drain comparison: node throughput under the serial,
# uniform-chunked and work-stealing frontier drains plus the steal
# telemetry proving subtrees redistributed) and exits non-zero if the
# arena-based solver's chosen sets diverge from the reference or any
# completed B&B search differs across drains or worker counts. Its
# `--steal` mode runs ONLY the drain comparison (fast enough for
# --quick; mode-tagged "steal"). The endtoend bench writes rust/BENCH_endtoend.json (ns per
# idle/round sim step, train-phase ns/round serial vs sharded, ring
# footprint) and exits non-zero if the incrementally-advanced forecast
# ring diverges from fresh-built windows OR sharded training diverges
# from serial. The campaign bench writes rust/BENCH_campaign.json
# (cells/sec serial vs parallel drain, trace-memoization hit rate) and
# exits non-zero if the report schema is invalid, the report is not
# byte-identical across worker counts, or the declarative builtin spec
# diverges from the legacy config::build path. The chaos bench writes
# rust/BENCH_chaos.json (ns/step with the fault injector on vs off) and
# exits non-zero if two identically seeded chaos runs differ, the
# injected faults leave no trace in the metrics, or a chaos-axis
# campaign diverges across worker counts. The journal bench writes
# rust/BENCH_journal.json (ns per write-ahead append, recovery cost of
# open + torn-tail scan + replay) and exits non-zero if a crashed-and-
# resumed run diverges — metrics or journal bytes — from an
# uninterrupted one, or a durable campaign resume diverges from a fresh
# single-pass report. The endtoend bench
# additionally gates the event-driven round FSM against the legacy loop
# (no-fault runs must be bit-identical) and the hierarchical two-tier
# aggregator against flat FedAvg (full-sim AggMode::Tree vs
# AggMode::Flat must be bit-identical). `--tree` runs ONLY the
# 1M-client flat-vs-tree scaling series + the skewed-domain stolen
# leaf-fill series (one giant domain, 1/2/8 pinned workers, steal
# counts recorded) + bitwise divergence gates, written to
# rust/BENCH_tree.json — fast enough for --quick.
#
# Worker counts everywhere honour FEDZERO_THREADS (see util::par); the
# determinism gates pin 1/2/8 workers explicitly, so they hold under
# any override.
#
# When a committed baseline (BENCH_<name>.baseline.json) exists next to a
# freshly written BENCH_<name>.json, the two are compared metric by
# metric: regressions >10% warn, >50% fail the run.
#
# >>> STILL OUTSTANDING (now eight PRs of perf work with no recorded
# >>> trajectory): no toolchain environment has ever run these benches,
# >>> so NO baseline is committed and the ratchet below is wired but
# >>> UNARMED. First CI run in a cargo environment must do this:
#
# ARMING / RE-RATCHETING THE BASELINES (run in a toolchain environment —
# the authoring container has no cargo, so the first arming must happen
# wherever CI actually runs):
#   1. ./ci.sh --arm            # green build/tests + fresh JSON, then
#                               # copies BENCH_*.json -> *.baseline.json
#   2. git add rust/BENCH_*.baseline.json && git commit
# Baselines are mode-tagged: a quick-mode baseline only gates quick-mode
# runs (the comparator skips mismatched modes), so arm with the mode CI
# uses. After an INTENTIONAL perf change, repeat 1–2 in the same
# environment; never copy a baseline produced on different hardware over
# an existing one — the ratchet compares absolute numbers.
set -euo pipefail
cd "$(dirname "$0")/rust"

# Compare a fresh bench JSON against a committed baseline, printing
# per-metric deltas. Direction is inferred from the metric name: ns/ms/
# bytes/mismatch metrics are lower-better, per_s/speedup higher-better;
# anything else is informational and skipped. Comparison is skipped (not
# failed) when the baseline is absent, python3 is missing, or the two
# files were produced in different bench modes (--quick vs default).
compare_bench() {
    local fresh="$1" base="$2"
    if [[ ! -f "$base" ]]; then
        echo "  (no baseline $base — skipping bench comparison)"
        return 0
    fi
    if ! command -v python3 >/dev/null 2>&1; then
        echo "  (python3 unavailable — skipping bench comparison)"
        return 0
    fi
    echo "== bench delta: $fresh vs $base (warn >10%, fail >50% regression) =="
    python3 - "$fresh" "$base" <<'PY'
import json, sys

fresh_path, base_path = sys.argv[1], sys.argv[2]
with open(fresh_path) as f:
    fresh = json.load(f)
with open(base_path) as f:
    base = json.load(f)

if fresh.get("mode") != base.get("mode"):
    print(f"  (bench mode {fresh.get('mode')!r} != baseline mode "
          f"{base.get('mode')!r} — skipping comparison)")
    sys.exit(0)

def flatten(prefix, node, out):
    if isinstance(node, dict):
        for k, v in node.items():
            flatten(f"{prefix}.{k}" if prefix else k, v, out)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            # index arrays by a stable key when one exists so points
            # still match after reordering
            key = str(i)
            if isinstance(v, dict):
                if "name" in v:
                    key = str(v["name"])
                elif "d_max" in v:
                    key = f"dmax{int(v['d_max'])}"
            flatten(f"{prefix}[{key}]", v, out)
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix] = float(node)

LOWER = ("ns_", "_ns", "_ms", "bytes", "mismatch", "divergence")
HIGHER = ("per_s", "speedup")

fa, ba = {}, {}
flatten("", fresh, fa)
flatten("", base, ba)
fails = warns = compared = 0
for k in sorted(fa):
    if k not in ba:
        continue
    new, old = fa[k], ba[k]
    leaf = k.rsplit(".", 1)[-1]
    if any(t in leaf for t in LOWER):
        reg = (new - old) / old if old else (1.0 if new > old else 0.0)
    elif any(t in leaf for t in HIGHER):
        reg = (old - new) / old if old else 0.0
    else:
        continue
    compared += 1
    mark = ""
    if reg > 0.50:
        mark, fails = "FAIL", fails + 1
    elif reg > 0.10:
        mark, warns = "WARN", warns + 1
    if mark or abs(reg) > 0.02:
        print(f"  {k:<58} {old:>14.1f} -> {new:>14.1f} "
              f"{reg * 100.0:>+8.1f}% {mark}")
print(f"  bench comparison: {compared} metrics, {warns} warnings, "
      f"{fails} failures")
sys.exit(1 if fails else 0)
PY
}

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== campaign smoke (--quick: schema + determinism + legacy gates) =="
cargo bench --bench campaign -- --quick
compare_bench BENCH_campaign.json BENCH_campaign.baseline.json

echo "== chaos smoke (--quick: seeded fault-injection determinism + visibility gates) =="
cargo bench --bench chaos -- --quick
compare_bench BENCH_chaos.json BENCH_chaos.baseline.json

echo "== tree aggregation gate (--tree: 1M-client flat-vs-tree bitwise + skewed stolen fill) =="
cargo bench --bench endtoend -- --tree
compare_bench BENCH_tree.json BENCH_tree.baseline.json

echo "== steal scheduler gate (--steal: skewed-tree B&B drains, bitwise at 1/2/8 workers) =="
cargo bench --bench selection -- --steal
compare_bench BENCH_selection.json BENCH_selection.baseline.json

echo "== journal smoke (--quick: crash-resume bit-identity + campaign-resume gates) =="
cargo bench --bench journal -- --quick
compare_bench BENCH_journal.json BENCH_journal.baseline.json

# Telemetry determinism gate: the SAME scenario run with the obs layer
# off and fully armed (counters + histograms + span tracing) must leave
# every deterministic output byte-identical — metrics file, write-ahead
# journal, snapshots, campaign report (the latter also across worker
# counts) — and the exported trace.json / TELEMETRY.json must be
# well-formed. Exit 1 on any divergence.
echo "== telemetry determinism gate (obs on vs off, byte-identical outputs) =="
FZ=./target/release/fedzero
OBS_TMP=$(mktemp -d)
trap 'rm -rf "$OBS_TMP"' EXIT
TRAIN_FLAGS=(--mock --days 1 --clients 20 --n 4 --dmax 30 --seed 3 --scale 0.2 --snapshot-every 3)
"$FZ" train "${TRAIN_FLAGS[@]}" --out "$OBS_TMP/metrics_off.json" \
    --checkpoint "$OBS_TMP/ckpt_off" >/dev/null
"$FZ" train "${TRAIN_FLAGS[@]}" --out "$OBS_TMP/metrics_on.json" \
    --checkpoint "$OBS_TMP/ckpt_on" \
    --trace "$OBS_TMP/trace.json" --telemetry "$OBS_TMP/TELEMETRY.json" >/dev/null
cmp "$OBS_TMP/metrics_off.json" "$OBS_TMP/metrics_on.json" \
    || { echo "TELEMETRY GATE FAILED: metrics diverged with telemetry on"; exit 1; }
diff -r "$OBS_TMP/ckpt_off" "$OBS_TMP/ckpt_on" >/dev/null \
    || { echo "TELEMETRY GATE FAILED: journal/snapshot bytes diverged with telemetry on"; exit 1; }
"$FZ" campaign smoke --workers 1 --out "$OBS_TMP/camp_off.json" >/dev/null
"$FZ" campaign smoke --workers 4 --out "$OBS_TMP/camp_on.json" \
    --telemetry "$OBS_TMP/TELEMETRY_camp.json" >/dev/null
cmp "$OBS_TMP/camp_off.json" "$OBS_TMP/camp_on.json" \
    || { echo "TELEMETRY GATE FAILED: campaign report diverged (telemetry on, 4 workers)"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    python3 - "$OBS_TMP/trace.json" "$OBS_TMP/TELEMETRY.json" <<'PY'
import json, sys

with open(sys.argv[1]) as f:
    trace = json.load(f)
evs = trace["traceEvents"]
assert isinstance(evs, list) and evs, "trace.json has no events"
for e in evs:
    for k in ("name", "ph", "ts", "dur", "pid", "tid"):
        assert k in e, f"trace event missing {k!r}: {e}"
    assert e["ph"] == "X", f"unexpected phase {e['ph']!r}"
    assert e["ts"] >= 0 and e["dur"] >= 0
names = {e["name"] for e in evs}
for phase in ("round", "select", "aggregate"):
    assert phase in names, f"missing {phase!r} span in trace.json"

with open(sys.argv[2]) as f:
    tele = json.load(f)
assert tele["schema"] == "fedzero-telemetry-v1", tele.get("schema")
subs = tele["subsystems"]
assert len(subs) >= 6, f"expected >= 6 subsystem sections, got {sorted(subs)}"
live = [s for s, sec in subs.items()
        if any(v > 0 for v in sec["counters"].values())
        or any(h["count"] > 0 for h in sec["histograms"].values())]
for s in ("engine", "tree", "journal"):
    assert s in live, f"{s} reported no activity (live: {live})"
print(f"  telemetry schema: ok ({len(evs)} trace events, "
      f"live subsystems: {', '.join(sorted(live))})")
PY
else
    echo "  (python3 unavailable — skipping telemetry schema checks)"
fi
rm -rf "$OBS_TMP"
trap - EXIT
echo "telemetry gate: ok (outputs byte-identical with obs armed)"

if [[ "${1:-}" == "--quick" ]]; then
    echo "CI OK (quick)"
    exit 0
fi

echo "== selection bench smoke (--quick) =="
cargo bench --bench selection -- --quick
compare_bench BENCH_selection.json BENCH_selection.baseline.json

echo "== endtoend bench smoke (--quick, ring + train + fsm divergence gates) =="
cargo bench --bench endtoend -- --quick
compare_bench BENCH_endtoend.json BENCH_endtoend.baseline.json

if [[ "${1:-}" == "--bench" ]]; then
    echo "== selection bench (default points) =="
    cargo bench --bench selection
    compare_bench BENCH_selection.json BENCH_selection.baseline.json
fi

if [[ "${1:-}" == "--arm" ]]; then
    echo "== arming bench baselines from this run =="
    for b in campaign chaos tree selection endtoend journal; do
        if [[ -f "BENCH_$b.json" ]]; then
            cp "BENCH_$b.json" "BENCH_$b.baseline.json"
            echo "  armed BENCH_$b.baseline.json"
        fi
    done
    echo "now commit them: git add rust/BENCH_*.baseline.json && git commit"
fi

echo "CI OK"
