//! Forecast-robustness study (the paper's Fig 7 at example scale):
//! FedZero with realistic forecast errors vs perfect forecasts vs missing
//! load forecasts, on the global scenario.
//!
//! Run: `make artifacts && cargo run --release --example forecast_robustness`

use fedzero::config::Scenario;
use fedzero::coordinator::{run_experiment, ExperimentSpec, StrategyKind};
use fedzero::trace::forecast::ErrorLevel;
use fedzero::util::stats;

fn main() -> anyhow::Result<()> {
    let variants: [(&str, ErrorLevel, ErrorLevel); 3] = [
        ("w/ error", ErrorLevel::Realistic, ErrorLevel::Realistic),
        ("w/o error", ErrorLevel::Perfect, ErrorLevel::Perfect),
        ("no load forecast", ErrorLevel::Realistic, ErrorLevel::Unavailable),
    ];
    println!("forecast robustness (tiny preset, 2 simulated days):\n");
    let mut results = Vec::new();
    for (name, energy_error, load_error) in variants {
        let spec = ExperimentSpec {
            preset: "tiny".into(),
            scenario: Scenario::Global,
            strategy: StrategyKind::FedZero,
            days: 2,
            n_clients: 40,
            n_per_round: 6,
            dataset_scale: 0.25,
            energy_error,
            load_error,
            eval_every: 8,
            eval_subset: 400,
            ..Default::default()
        };
        let r = run_experiment(&spec)?;
        let durs = r.metrics.round_durations_min();
        println!(
            "  {:<18} best acc {:>5.1}%  energy {:>6.2} kWh  rounds {:>4}  dur p50/p95 {:>4.1}/{:>4.1} min",
            name,
            r.metrics.best_accuracy() * 100.0,
            r.metrics.total_energy_kwh(),
            r.metrics.rounds.len(),
            stats::percentile(&durs, 50.0),
            stats::percentile(&durs, 95.0),
        );
        results.push((name, r));
    }
    // robustness claim: with-error accuracy within a few points of perfect
    let with_err = results[0].1.metrics.best_accuracy();
    let perfect = results[1].1.metrics.best_accuracy();
    println!(
        "\naccuracy gap (perfect - realistic): {:+.2} pp — FedZero converges to \
         the same accuracy under forecast errors (paper §5.4)",
        (perfect - with_err) * 100.0
    );
    Ok(())
}
