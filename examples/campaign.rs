//! Declarative campaign quickstart: build a sweep in code (custom solar
//! sites × Dirichlet α × battery × churn), drain it across workers, and
//! print the deterministic report — the programmatic twin of
//! `fedzero campaign <spec.json>`.
//!
//!   cargo run --release --example campaign

use anyhow::Result;
use fedzero::coordinator::StrategyKind;
use fedzero::scenario::campaign::{run_campaign, CampaignSpec};
use fedzero::scenario::{ChurnSpec, EnvSpec, SiteSet};
use fedzero::trace::solar::Site;
use fedzero::util::par;

fn main() -> Result<()> {
    // an environment the paper never shipped: two hemispheres, one
    // cloudless desert site, asymmetric capacity
    let islands = EnvSpec {
        sites: SiteSet::Custom(vec![
            Site::new("Reykjavik", 64.1, 0.0, 0.55),
            Site::new("Atacama", -24.5, -4.0, 0.05),
            Site::new("Nairobi", -1.3, 3.0, 0.3),
        ]),
        capacity_w: vec![600.0, 1200.0, 800.0],
        ..EnvSpec::global()
    };

    let mut spec = CampaignSpec::smoke();
    spec.name = "islands-robustness".into();
    spec.n_clients = 24;
    spec.n_per_round = 5;
    spec.dataset_scale = 0.2;
    spec.target_accuracy = 0.4;
    spec.envs = vec![("global".into(), EnvSpec::global()), ("islands".into(), islands)];
    spec.alphas = vec![0.1, 0.5];
    spec.battery_axis = vec![0.0, 400.0];
    spec.churn_axis = vec![
        None,
        Some(ChurnSpec { outages_per_day: 2.0, mean_outage_min: 60.0 }),
    ];
    spec.strategies = vec![StrategyKind::FedZero, StrategyKind::Random];

    let workers = par::threads();
    let cells = spec.expand().len();
    println!("expanding {cells} cells across {workers} workers...\n");
    let run = run_campaign(&spec, workers)?;

    println!(
        "{:<56} {:>6} {:>9} {:>9} {:>8} {:>7}",
        "cell", "rounds", "best acc", "kWh", "waste", "jain"
    );
    for r in &run.results {
        println!(
            "{:<56} {:>6} {:>8.1}% {:>9.2} {:>8.2} {:>7.3}",
            r.cell.label,
            r.rounds,
            r.best_accuracy * 100.0,
            r.energy_kwh,
            r.wasted_kwh,
            r.fairness_jain,
        );
    }
    println!(
        "\n{cells} cells in {:.1}s — memoization saved {}/{} env builds",
        run.wall_s,
        run.memo_hits,
        run.memo_hits + run.memo_misses,
    );
    std::fs::write("CAMPAIGN_report.json", run.report_json().to_string_pretty())?;
    println!("wrote CAMPAIGN_report.json (byte-identical for any worker count)");
    Ok(())
}
