//! Selection scalability (the paper's Fig 8 claim: returns within two
//! minutes even at 100k clients / 100k domains / 1440 timesteps).
//!
//! Pure-selection workload — no artifacts needed.
//! Run: `cargo run --release --example scalability [--max 100000]`

use std::time::Instant;

use fedzero::solver::mip::{greedy, SelClient, SelInstance};
use fedzero::util::cli::Args;
use fedzero::util::rng::Rng;

fn instance(c: usize, p: usize, t: usize, seed: u64) -> SelInstance {
    let mut rng = Rng::new(seed);
    SelInstance {
        n: 10,
        clients: (0..c)
            .map(|_| {
                let m_min = rng.range_f64(5.0, 40.0);
                SelClient {
                    domain: rng.below(p),
                    sigma: rng.range_f64(0.1, 10.0),
                    delta: rng.range_f64(0.05, 0.5),
                    m_min,
                    m_max: m_min * 5.0,
                    spare: (0..t)
                        .map(|_| rng.range_f64(0.0, 40.0) as f32)
                        .collect(),
                }
            })
            .collect(),
        energy: (0..p)
            .map(|_| {
                (0..t).map(|_| rng.range_f64(0.0, 14.0) as f32).collect()
            })
            .collect(),
    }
}

fn main() {
    let args = Args::parse_from(std::env::args().skip(1));
    let max = args.get_usize("max", 100_000);
    println!("selection scalability (greedy solver, n=10):\n");
    println!(
        "{:>10} {:>10} {:>8} {:>12} {:>10}",
        "clients", "domains", "steps", "runtime", "objective"
    );
    let mut scale = 100usize;
    while scale <= max {
        let (c, p, t) = (scale, (scale / 10).max(1), 60);
        let inst = instance(c, p, t, 7);
        let t0 = Instant::now();
        let sol = greedy(&inst, 1);
        println!(
            "{:>10} {:>10} {:>8} {:>12} {:>10.1}",
            c,
            p,
            t,
            format!("{:.3} s", t0.elapsed().as_secs_f64()),
            sol.objective
        );
        scale *= 10;
    }
    if max >= 100_000 {
        // the paper's biggest configuration: 100k clients, 100k domains,
        // 24 h at 1-minute resolution
        let inst = instance(100_000, 100_000, 1_440, 8);
        let t0 = Instant::now();
        let sol = greedy(&inst, 1);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:>10} {:>10} {:>8} {:>12} {:>10.1}   <- paper's largest setting",
            100_000,
            100_000,
            1_440,
            format!("{dt:.2} s"),
            sol.objective
        );
        println!(
            "\npaper: <= 2 minutes at this scale; this machine: {dt:.1} s — {}",
            if dt <= 120.0 { "WITHIN the envelope" } else { "outside the envelope" }
        );
    }
}
