//! Quickstart: the smallest end-to-end FedZero run.
//!
//! Loads the `tiny` AOT artifacts, builds a 20-client/10-domain global
//! solar scenario, trains with FedZero's selection for one simulated day
//! and prints the accuracy trajectory.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use fedzero::config::Scenario;
use fedzero::coordinator::{run_experiment, ExperimentSpec, StrategyKind};

fn main() -> anyhow::Result<()> {
    let spec = ExperimentSpec {
        preset: "tiny".into(),
        scenario: Scenario::Global,
        strategy: StrategyKind::FedZero,
        days: 1,
        n_clients: 20,
        n_per_round: 4,
        d_max: 60,
        dataset_scale: 0.15,
        eval_every: 10,
        eval_subset: 256,
        ..Default::default()
    };
    println!("quickstart: 20 clients, 10 solar domains, 1 simulated day");
    let report = run_experiment(&spec)?;

    println!("\naccuracy trajectory:");
    for e in &report.metrics.evals {
        println!(
            "  day {:>5.2}  round {:>4}  acc {:>5.1}%  loss {:.3}  energy {:>5.2} kWh",
            e.step as f64 / 1440.0,
            e.round,
            e.accuracy * 100.0,
            e.loss,
            e.cumulative_kwh
        );
    }
    println!(
        "\n{} rounds, best accuracy {:.1}%, {:.2} kWh total, {} train steps",
        report.metrics.rounds.len(),
        report.metrics.best_accuracy() * 100.0,
        report.metrics.total_energy_kwh(),
        report.steps_executed,
    );
    println!("all training ran on renewable excess energy only [ok]");
    Ok(())
}
