//! End-to-end driver (the EXPERIMENTS.md §E2E run): trains the `vision`
//! model (CIFAR-100-like task, ~112k-parameter MLP through the Pallas
//! dense kernels) federated across clients in 10 global solar domains
//! for several hundred rounds under FedZero, with Random as the reference,
//! and logs the full loss/accuracy curve plus energy accounting.
//!
//! Run: `make artifacts && cargo run --release --example global_solar`
//! (pass --days N / --clients N / --scale X to resize)

use fedzero::config::Scenario;
use fedzero::coordinator::{run_experiment, ExperimentSpec, StrategyKind};
use fedzero::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let days = args.get_usize("days", 2);
    let base = ExperimentSpec {
        preset: "vision".into(),
        scenario: Scenario::Global,
        strategy: StrategyKind::FedZero,
        days,
        n_clients: args.get_usize("clients", 60),
        n_per_round: args.get_usize("n", 8),
        d_max: 60,
        dataset_scale: args.get_f64("scale", 0.4),
        eval_every: 10,
        eval_subset: 600,
        seed: args.get_usize("seed", 0) as u64,
        ..Default::default()
    };
    println!(
        "global_solar e2e: vision preset, {} clients, {} days, FedZero vs Random",
        base.n_clients, base.days
    );

    std::fs::create_dir_all("results").ok();
    for strategy in [StrategyKind::FedZero, StrategyKind::Random] {
        let spec = ExperimentSpec { strategy, ..base.clone() };
        let t0 = std::time::Instant::now();
        let report = run_experiment(&spec)?;
        println!(
            "\n=== {} ===  ({:.1}s wallclock, {} PJRT train steps)",
            strategy.name(),
            t0.elapsed().as_secs_f64(),
            report.steps_executed
        );
        println!("loss/accuracy curve:");
        for e in &report.metrics.evals {
            println!(
                "  day {:>5.2}  round {:>4}  loss {:>6.3}  acc {:>5.1}%  {:>6.2} kWh",
                e.step as f64 / 1440.0,
                e.round,
                e.loss,
                e.accuracy * 100.0,
                e.cumulative_kwh
            );
        }
        println!("{}", report.metrics.summary(strategy.name()));
        let path = format!(
            "results/global_solar_{}.json",
            strategy.name().replace([' ', '.'], "_")
        );
        report.metrics.save(std::path::Path::new(&path))?;
        println!("wrote {path}");
    }
    Ok(())
}
