//! Device profiles — paper Table 2, verbatim.
//!
//! Three client types (small/mid/large) roughly modelled on T4, V100 and
//! A100 GPUs with downscaled throughput; per-model samples/minute and max
//! power draw. Capacity `m_c` and efficiency `δ_c` derive from these:
//!
//!   m_c  = samples_per_min · step_min / batch_size      [batches/step]
//!   δ_c  = max_power_W · (batch_size / samples_per_min) / 60   [Wh/batch]

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceType {
    Small,
    Mid,
    Large,
}

/// The paper's four model/dataset columns in Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// DenseNet-121 on CIFAR-100
    Vision,
    /// EfficientNet-B1 on Tiny ImageNet
    ImageNet,
    /// two-layer LSTM on Shakespeare
    Seq,
    /// KWT-1 on Google Speech Commands
    Speech,
}

impl ModelKind {
    pub fn from_preset(name: &str) -> ModelKind {
        match name {
            "vision" | "tiny" => ModelKind::Vision,
            "imagenet" => ModelKind::ImageNet,
            "seq" => ModelKind::Seq,
            "speech" => ModelKind::Speech,
            other => panic!("unknown preset {other}"),
        }
    }
}

impl DeviceType {
    pub const ALL: [DeviceType; 3] =
        [DeviceType::Small, DeviceType::Mid, DeviceType::Large];

    /// max power draw in W (Table 2)
    pub fn max_power_w(self) -> f64 {
        match self {
            DeviceType::Small => 70.0,
            DeviceType::Mid => 300.0,
            DeviceType::Large => 700.0,
        }
    }

    /// samples per minute (Table 2)
    pub fn samples_per_min(self, model: ModelKind) -> f64 {
        match (self, model) {
            (DeviceType::Small, ModelKind::Vision) => 110.0,
            (DeviceType::Small, ModelKind::ImageNet) => 118.0,
            (DeviceType::Small, ModelKind::Seq) => 276.0,
            (DeviceType::Small, ModelKind::Speech) => 87.0,
            (DeviceType::Mid, ModelKind::Vision) => 384.0,
            (DeviceType::Mid, ModelKind::ImageNet) => 411.0,
            (DeviceType::Mid, ModelKind::Seq) => 956.0,
            (DeviceType::Mid, ModelKind::Speech) => 303.0,
            (DeviceType::Large, ModelKind::Vision) => 742.0,
            (DeviceType::Large, ModelKind::ImageNet) => 795.0,
            (DeviceType::Large, ModelKind::Seq) => 1856.0,
            (DeviceType::Large, ModelKind::Speech) => 586.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DeviceType::Small => "small",
            DeviceType::Mid => "mid",
            DeviceType::Large => "large",
        }
    }

    pub fn sample(rng: &mut Rng) -> DeviceType {
        Self::ALL[rng.below(3)]
    }
}

/// Resolved per-client constants.
#[derive(Clone, Debug)]
pub struct ClientProfile {
    pub device: DeviceType,
    pub model: ModelKind,
    /// m_c: max batches per timestep
    pub batches_per_step: f64,
    /// δ_c: Wh per batch
    pub wh_per_batch: f64,
}

impl ClientProfile {
    pub fn new(
        device: DeviceType,
        model: ModelKind,
        batch_size: usize,
        step_minutes: f64,
    ) -> ClientProfile {
        let spm = device.samples_per_min(model);
        let batches_per_step = spm * step_minutes / batch_size as f64;
        let wh_per_batch =
            device.max_power_w() * (batch_size as f64 / spm) / 60.0;
        ClientProfile { device, model, batches_per_step, wh_per_batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        assert_eq!(DeviceType::Small.max_power_w(), 70.0);
        assert_eq!(DeviceType::Large.samples_per_min(ModelKind::Seq), 1856.0);
        assert_eq!(DeviceType::Mid.samples_per_min(ModelKind::Speech), 303.0);
    }

    #[test]
    fn derived_capacity_and_efficiency() {
        // mid + vision: 384 samples/min, batch 10 => 38.4 batches/min
        let p = ClientProfile::new(DeviceType::Mid, ModelKind::Vision, 10, 1.0);
        assert!((p.batches_per_step - 38.4).abs() < 1e-9);
        // δ: 300 W × (10/384) min / 60 = 0.1302.. Wh/batch
        assert!((p.wh_per_batch - 300.0 * (10.0 / 384.0) / 60.0).abs() < 1e-12);
    }

    #[test]
    fn energy_per_sample_ordering() {
        // larger devices are faster but in the paper's Table 2 they are not
        // necessarily more energy-efficient per sample: check small < large
        // per-batch energy ordering holds for vision
        let s = ClientProfile::new(DeviceType::Small, ModelKind::Vision, 10, 1.0);
        let l = ClientProfile::new(DeviceType::Large, ModelKind::Vision, 10, 1.0);
        assert!(s.wh_per_batch < l.wh_per_batch);
        assert!(s.batches_per_step < l.batches_per_step);
    }

    #[test]
    fn full_power_full_capacity_consistency() {
        // computing at full capacity for one step must consume exactly
        // max_power × step duration
        for device in DeviceType::ALL {
            let p = ClientProfile::new(device, ModelKind::ImageNet, 10, 1.0);
            let wh = p.batches_per_step * p.wh_per_batch;
            let expect = device.max_power_w() / 60.0; // 1 minute
            assert!((wh - expect).abs() < 1e-9, "{device:?}");
        }
    }
}
