//! FL client modelling: device profiles (paper Table 2), per-client
//! capacity/efficiency constants, and the static client descriptor used by
//! selection and simulation.

pub mod profile;

pub use profile::{ClientProfile, DeviceType, ModelKind};

/// Static description of one registered client (paper §4.1): capacity
/// `m_c` (batches/timestep), efficiency `δ_c` (Wh/batch), power domain,
/// and its local data shard.
#[derive(Clone, Debug)]
pub struct ClientInfo {
    pub id: usize,
    pub domain: usize,
    pub profile: ClientProfile,
    /// indices into the training split owned by this client
    pub samples: Vec<usize>,
    /// minimum batches per round (1 local epoch in the paper)
    pub m_min: f64,
    /// maximum batches per round (5 local epochs)
    pub m_max: f64,
}

impl ClientInfo {
    /// Build from a profile + data shard with the paper's 1–5 local epoch
    /// bounds at the given batch size.
    pub fn new(
        id: usize,
        domain: usize,
        profile: ClientProfile,
        samples: Vec<usize>,
        batch_size: usize,
    ) -> Self {
        let batches_per_epoch =
            (samples.len() as f64 / batch_size as f64).ceil().max(1.0);
        ClientInfo {
            id,
            domain,
            profile,
            samples,
            m_min: batches_per_epoch,
            m_max: 5.0 * batches_per_epoch,
        }
    }

    /// capacity in batches per timestep
    pub fn capacity(&self) -> f64 {
        self.profile.batches_per_step
    }

    /// energy per batch in Wh
    pub fn delta(&self) -> f64 {
        self.profile.wh_per_batch
    }

    pub fn num_samples(&self) -> usize {
        self.samples.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_bounds_follow_shard_size() {
        let p = ClientProfile::new(DeviceType::Mid, ModelKind::Vision, 10, 1.0);
        let c = ClientInfo::new(0, 0, p, (0..95).collect(), 10);
        assert_eq!(c.m_min, 10.0); // ceil(95/10)
        assert_eq!(c.m_max, 50.0);
        assert_eq!(c.num_samples(), 95);
    }

    #[test]
    fn tiny_shard_still_has_one_batch() {
        let p = ClientProfile::new(DeviceType::Small, ModelKind::Seq, 10, 1.0);
        let c = ClientInfo::new(1, 2, p, vec![7], 10);
        assert_eq!(c.m_min, 1.0);
        assert_eq!(c.m_max, 5.0);
    }
}
