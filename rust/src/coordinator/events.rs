//! Client-event vocabulary and the deterministic event queue that
//! drives the round state machine ([`super::fsm`]).
//!
//! Modeled on the `state_machine` / `events` split used by production
//! FL coordinators (e.g. xaynet): the engine never mutates round
//! liveness directly — every change of client state during a round
//! (check-in, dropout, rejoin, update submission, deadline expiry)
//! arrives as a [`ClientEvent`] popped from an [`EventQueue`].
//!
//! # Determinism rules
//!
//! The queue is a min-heap ordered by `(at, seq)` where `seq` is a
//! monotone insertion counter. Two events due at the same timestep are
//! therefore delivered in exactly the order they were pushed, and the
//! push order itself is deterministic (round seeding iterates selected
//! slots in ascending order; chaos schedules are pure functions of
//! `(seed, client, round start)` — see [`crate::sim::chaos`]). No wall
//! clock, no thread identity, no hash-map iteration feeds the queue,
//! so a replay with the same seeds delivers the same events in the
//! same order regardless of worker count.
//!
//! # Epoch fencing
//!
//! Every event carries the epoch token of the round that emitted it.
//! The state machine compares that token against its current epoch and
//! ignores (or, for [`ClientEvent::UpdateSubmitted`], rejects and
//! meters) anything stale. This is what lets the queue persist across
//! rounds: a delayed update pushed during round `r` can surface while
//! round `r + 1` is running — or while the engine is idle — and is
//! fenced off instead of silently aggregated.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One client-visible occurrence, tagged with the epoch of the round
/// that scheduled it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClientEvent {
    /// A selected client acknowledges the round assignment.
    CheckIn { client: usize, epoch: u64 },
    /// A client delivers its model update for the round with the given
    /// epoch token. Stale tokens are rejected and metered as waste.
    UpdateSubmitted { client: usize, epoch: u64 },
    /// A client goes offline (churn outage window opens, or a chaos
    /// fault fires). Liveness is a depth counter, so overlapping
    /// windows from independent sources compose.
    Dropout { client: usize, epoch: u64 },
    /// A client comes back online (matching a prior `Dropout`).
    Rejoin { client: usize, epoch: u64 },
    /// The round deadline (`SelectionDecision::max_duration`) expired.
    Timeout { epoch: u64 },
}

impl ClientEvent {
    /// The epoch token this event is fenced to.
    pub fn epoch(&self) -> u64 {
        match *self {
            ClientEvent::CheckIn { epoch, .. }
            | ClientEvent::UpdateSubmitted { epoch, .. }
            | ClientEvent::Dropout { epoch, .. }
            | ClientEvent::Rejoin { epoch, .. }
            | ClientEvent::Timeout { epoch } => epoch,
        }
    }
}

/// An event scheduled for delivery at timestep `at`. Orders by
/// `(at, seq)` ascending — `seq` breaks ties by insertion order.
#[derive(Clone, Copy, Debug)]
struct TimedEvent {
    at: usize,
    seq: u64,
    ev: ClientEvent,
}

impl PartialEq for TimedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for TimedEvent {}

impl Ord for TimedEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse so the smallest (at, seq)
        // pops first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}
impl PartialOrd for TimedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic time-ordered event queue (min-heap over `(at, seq)`).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<TimedEvent>,
    next_seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Schedule `ev` for delivery at timestep `at`.
    pub fn push(&mut self, at: usize, ev: ClientEvent) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(TimedEvent { at, seq, ev });
    }

    /// Pop the next event due at or before `now`, if any.
    pub fn pop_due(&mut self, now: usize) -> Option<ClientEvent> {
        match self.heap.peek() {
            Some(te) if te.at <= now => Some(self.heap.pop().unwrap().ev),
            _ => None,
        }
    }

    /// Delivery time of the next pending event, if any.
    pub fn peek_at(&self) -> Option<usize> {
        self.heap.peek().map(|te| te.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop every pending event (used only by tests; the engine fences
    /// stale events by epoch instead of clearing).
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Every pending event in delivery order (`(at, seq)` ascending) —
    /// the checkpointing view. Re-pushing the returned pairs into a
    /// fresh queue (in order) reproduces the exact delivery sequence:
    /// fresh `seq` counters are re-minted monotonically, so relative
    /// order within a timestep is preserved bit for bit.
    pub fn to_sorted_vec(&self) -> Vec<(usize, ClientEvent)> {
        let mut v: Vec<&TimedEvent> = self.heap.iter().collect();
        v.sort_by_key(|te| (te.at, te.seq));
        v.into_iter().map(|te| (te.at, te.ev)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_then_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5, ClientEvent::Timeout { epoch: 1 });
        q.push(2, ClientEvent::Dropout { client: 3, epoch: 1 });
        q.push(2, ClientEvent::Rejoin { client: 3, epoch: 1 });
        q.push(0, ClientEvent::CheckIn { client: 0, epoch: 1 });

        assert_eq!(q.pop_due(10), Some(ClientEvent::CheckIn { client: 0, epoch: 1 }));
        // same `at`: insertion order (Dropout pushed before Rejoin)
        assert_eq!(q.pop_due(10), Some(ClientEvent::Dropout { client: 3, epoch: 1 }));
        assert_eq!(q.pop_due(10), Some(ClientEvent::Rejoin { client: 3, epoch: 1 }));
        assert_eq!(q.pop_due(10), Some(ClientEvent::Timeout { epoch: 1 }));
        assert_eq!(q.pop_due(10), None);
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(7, ClientEvent::Timeout { epoch: 0 });
        assert_eq!(q.pop_due(6), None);
        assert_eq!(q.peek_at(), Some(7));
        assert!(q.pop_due(7).is_some());
        assert!(q.is_empty());
    }

    #[test]
    fn sorted_snapshot_rebuilds_identical_delivery_order() {
        let mut q = EventQueue::new();
        q.push(5, ClientEvent::Timeout { epoch: 2 });
        q.push(2, ClientEvent::Dropout { client: 3, epoch: 2 });
        q.push(2, ClientEvent::Rejoin { client: 3, epoch: 2 });
        q.push(9, ClientEvent::UpdateSubmitted { client: 1, epoch: 2 });
        let snap = q.to_sorted_vec();
        assert_eq!(snap.len(), 4);
        let mut rebuilt = EventQueue::new();
        for (at, ev) in snap {
            rebuilt.push(at, ev);
        }
        loop {
            let a = q.pop_due(usize::MAX);
            let b = rebuilt.pop_due(usize::MAX);
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn interleaved_push_pop_keeps_ordering() {
        let mut q = EventQueue::new();
        q.push(3, ClientEvent::Dropout { client: 0, epoch: 2 });
        q.push(1, ClientEvent::Dropout { client: 1, epoch: 2 });
        assert_eq!(q.pop_due(5), Some(ClientEvent::Dropout { client: 1, epoch: 2 }));
        q.push(2, ClientEvent::Rejoin { client: 1, epoch: 2 });
        assert_eq!(q.pop_due(5), Some(ClientEvent::Rejoin { client: 1, epoch: 2 }));
        assert_eq!(q.pop_due(5), Some(ClientEvent::Dropout { client: 0, epoch: 2 }));
    }
}
