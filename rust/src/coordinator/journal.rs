//! Write-ahead event journal for the durable coordinator.
//!
//! Every round decision and every applied [`ClientEvent`] is appended
//! here *before* it takes effect on simulation state, so a crash at any
//! timestep loses at most the record being written. On reopen the torn
//! tail is detected and truncated, and the surviving prefix is replayed
//! through a scratch [`RoundFsm`] — the same `apply`/epoch-fencing
//! machinery that produced it — to prove the log is internally
//! consistent before the engine trusts it.
//!
//! # Framing
//!
//! Records are length-prefixed JSON with a checksum header — no new
//! dependencies, human-inspectable payloads, torn writes detectable at
//! any byte offset:
//!
//! ```text
//! ┌────────────────┬──────────────────────┬──────────────────┐
//! │ u32 LE len     │ u32 LE FNV-1a(bytes) │ len payload bytes │
//! └────────────────┴──────────────────────┴──────────────────┘
//! ```
//!
//! A record is durable iff its full frame is present, its checksum
//! matches, and its payload parses as a known [`JournalRecord`]. The
//! first record failing any of those checks marks the torn tail:
//! everything from there on is dropped (`Journal::open` truncates the
//! file back to the durable prefix). Appends flush eagerly.
//!
//! # Record vocabulary
//!
//! * [`JournalRecord::RoundStart`] — the validated selection decision
//!   plus the epoch token the round minted.
//! * [`JournalRecord::Event`] — one applied client event, journaled at
//!   application time (journal order = application order, which is what
//!   makes replay exact).
//! * [`JournalRecord::RoundClose`] — the round's outcome: submitted
//!   slots and participants, cross-checked on replay.
//! * [`JournalRecord::SnapshotMark`] — a snapshot checkpoint was
//!   persisted at this round boundary. Resume truncates the journal
//!   back to the mark matching the snapshot it loads, then re-executed
//!   rounds re-append byte-identical records — so after a crash +
//!   resume, the final journal is byte-identical to an uninterrupted
//!   durable run's.

use std::fs::OpenOptions;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::selection::SelectionDecision;
use crate::util::fsx;
use crate::util::json::{num, obj, parse_u64_hex, s, u64_hex, Json};
use crate::util::obs;

use super::events::{ClientEvent, EventQueue};
use super::fsm::RoundFsm;

/// Hard sanity cap on one record's payload (a RoundStart listing every
/// client of a 1M-client round stays far below this; anything larger in
/// a length header means the header bytes are garbage).
const MAX_RECORD_BYTES: usize = 64 << 20;

/// 32-bit FNV-1a over the payload bytes.
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

// -- ClientEvent codec -------------------------------------------------------

/// Encode one event (epoch tokens as lossless hex — see
/// [`crate::util::json::u64_hex`]).
pub fn event_to_json(ev: &ClientEvent) -> Json {
    let (kind, client, epoch) = match *ev {
        ClientEvent::CheckIn { client, epoch } => ("check_in", Some(client), epoch),
        ClientEvent::UpdateSubmitted { client, epoch } => ("update", Some(client), epoch),
        ClientEvent::Dropout { client, epoch } => ("dropout", Some(client), epoch),
        ClientEvent::Rejoin { client, epoch } => ("rejoin", Some(client), epoch),
        ClientEvent::Timeout { epoch } => ("timeout", None, epoch),
    };
    let mut pairs = vec![("kind", s(kind)), ("epoch", u64_hex(epoch))];
    if let Some(c) = client {
        pairs.push(("client", num(c as f64)));
    }
    obj(pairs)
}

pub fn event_from_json(j: &Json) -> Result<ClientEvent, String> {
    let kind = j
        .get("kind")
        .and_then(|k| k.as_str())
        .ok_or("event missing kind")?;
    let epoch = parse_u64_hex(j.get("epoch").ok_or("event missing epoch")?)?;
    let client = || -> Result<usize, String> {
        j.get("client")
            .and_then(|c| c.as_usize())
            .ok_or_else(|| format!("{kind} event missing client"))
    };
    Ok(match kind {
        "check_in" => ClientEvent::CheckIn { client: client()?, epoch },
        "update" => ClientEvent::UpdateSubmitted { client: client()?, epoch },
        "dropout" => ClientEvent::Dropout { client: client()?, epoch },
        "rejoin" => ClientEvent::Rejoin { client: client()?, epoch },
        "timeout" => ClientEvent::Timeout { epoch },
        other => return Err(format!("unknown event kind {other:?}")),
    })
}

fn usize_arr(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| num(x as f64)).collect())
}

fn parse_usize_arr(j: &Json, what: &str) -> Result<Vec<usize>, String> {
    j.as_arr()
        .ok_or_else(|| format!("{what} is not an array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| format!("{what} holds a non-integer")))
        .collect()
}

fn get_usize(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| format!("record missing {key}"))
}

// -- records -----------------------------------------------------------------

/// One durable entry in the write-ahead log.
#[derive(Clone, Debug, PartialEq)]
pub enum JournalRecord {
    /// A validated decision started a round (journaled before the first
    /// training step executes).
    RoundStart {
        round: usize,
        /// the epoch token `begin_round` minted for this round
        epoch: u64,
        t0: usize,
        round_cap: usize,
        n_clients: usize,
        clients: Vec<usize>,
        n_required: usize,
        unconstrained: bool,
    },
    /// One client event, journaled at the step it was applied.
    Event { at: usize, ev: ClientEvent },
    /// The round closed; replay cross-checks the submitted slots.
    RoundClose {
        round: usize,
        timed_out: bool,
        /// slot indices (into the round's client list) that submitted
        submitted: Vec<usize>,
        /// client ids whose work entered the aggregate
        participants: Vec<usize>,
    },
    /// A snapshot checkpoint covering everything up to `round` was
    /// persisted; resume truncates back to here.
    SnapshotMark { round: usize, t: usize },
}

impl JournalRecord {
    pub fn to_json(&self) -> Json {
        match self {
            JournalRecord::RoundStart {
                round,
                epoch,
                t0,
                round_cap,
                n_clients,
                clients,
                n_required,
                unconstrained,
            } => obj(vec![
                ("type", s("round_start")),
                ("round", num(*round as f64)),
                ("epoch", u64_hex(*epoch)),
                ("t0", num(*t0 as f64)),
                ("round_cap", num(*round_cap as f64)),
                ("n_clients", num(*n_clients as f64)),
                ("clients", usize_arr(clients)),
                ("n_required", num(*n_required as f64)),
                ("unconstrained", Json::Bool(*unconstrained)),
            ]),
            JournalRecord::Event { at, ev } => obj(vec![
                ("type", s("event")),
                ("at", num(*at as f64)),
                ("ev", event_to_json(ev)),
            ]),
            JournalRecord::RoundClose { round, timed_out, submitted, participants } => {
                obj(vec![
                    ("type", s("round_close")),
                    ("round", num(*round as f64)),
                    ("timed_out", Json::Bool(*timed_out)),
                    ("submitted", usize_arr(submitted)),
                    ("participants", usize_arr(participants)),
                ])
            }
            JournalRecord::SnapshotMark { round, t } => obj(vec![
                ("type", s("snapshot_mark")),
                ("round", num(*round as f64)),
                ("t", num(*t as f64)),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<JournalRecord, String> {
        let ty = j
            .get("type")
            .and_then(|t| t.as_str())
            .ok_or("record missing type")?;
        Ok(match ty {
            "round_start" => JournalRecord::RoundStart {
                round: get_usize(j, "round")?,
                epoch: parse_u64_hex(j.get("epoch").ok_or("record missing epoch")?)?,
                t0: get_usize(j, "t0")?,
                round_cap: get_usize(j, "round_cap")?,
                n_clients: get_usize(j, "n_clients")?,
                clients: parse_usize_arr(
                    j.get("clients").ok_or("record missing clients")?,
                    "clients",
                )?,
                n_required: get_usize(j, "n_required")?,
                unconstrained: j
                    .get("unconstrained")
                    .and_then(|b| b.as_bool())
                    .ok_or("record missing unconstrained")?,
            },
            "event" => JournalRecord::Event {
                at: get_usize(j, "at")?,
                ev: event_from_json(j.get("ev").ok_or("record missing ev")?)?,
            },
            "round_close" => JournalRecord::RoundClose {
                round: get_usize(j, "round")?,
                timed_out: j
                    .get("timed_out")
                    .and_then(|b| b.as_bool())
                    .ok_or("record missing timed_out")?,
                submitted: parse_usize_arr(
                    j.get("submitted").ok_or("record missing submitted")?,
                    "submitted",
                )?,
                participants: parse_usize_arr(
                    j.get("participants").ok_or("record missing participants")?,
                    "participants",
                )?,
            },
            "snapshot_mark" => JournalRecord::SnapshotMark {
                round: get_usize(j, "round")?,
                t: get_usize(j, "t")?,
            },
            other => return Err(format!("unknown record type {other:?}")),
        })
    }
}

// -- the journal file --------------------------------------------------------

/// Append-only write-ahead log with torn-tail recovery.
pub struct Journal {
    path: PathBuf,
    file: std::fs::File,
    len: u64,
    /// `(snapshot round, byte offset just past the mark record)` for
    /// every durable [`JournalRecord::SnapshotMark`], append order
    marks: Vec<(usize, u64)>,
}

impl Journal {
    /// Start a fresh journal (truncating any existing file).
    pub fn create(path: &Path) -> Result<Journal> {
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .append(true)
            .open(path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        file.set_len(0)
            .with_context(|| format!("truncating journal {}", path.display()))?;
        Ok(Journal { path: path.to_path_buf(), file, len: 0, marks: Vec::new() })
    }

    /// Open an existing journal: scan every frame, stop at the first
    /// torn/corrupt record, truncate the file back to the durable
    /// prefix, and return the surviving records.
    pub fn open(path: &Path) -> Result<(Journal, Vec<JournalRecord>)> {
        let bytes = fsx::read(path)?;
        let mut records = Vec::new();
        let mut marks = Vec::new();
        let mut off = 0usize;
        while off + 8 <= bytes.len() {
            let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
            let sum = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
            if len > MAX_RECORD_BYTES || off + 8 + len > bytes.len() {
                break; // torn mid-payload (or garbage length header)
            }
            let payload = &bytes[off + 8..off + 8 + len];
            if fnv1a(payload) != sum {
                break; // torn or corrupted payload
            }
            let Ok(text) = std::str::from_utf8(payload) else { break };
            let Ok(doc) = Json::parse(text) else { break };
            let Ok(rec) = JournalRecord::from_json(&doc) else { break };
            off += 8 + len;
            if let JournalRecord::SnapshotMark { round, .. } = rec {
                marks.push((round, off as u64));
            }
            records.push(rec);
        }
        let file = OpenOptions::new()
            .write(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        if off < bytes.len() {
            file.set_len(off as u64).with_context(|| {
                format!("truncating torn tail of {}", path.display())
            })?;
        }
        Ok((
            Journal { path: path.to_path_buf(), file, len: off as u64, marks },
            records,
        ))
    }

    /// Append one record (frame + eager flush). Returns the byte length
    /// of the journal after the append.
    pub fn append(&mut self, rec: &JournalRecord) -> Result<u64> {
        let payload = rec.to_json().to_string_compact().into_bytes();
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        obs::add(obs::Ctr::JournalFrames, 1);
        obs::add(obs::Ctr::JournalBytes, frame.len() as u64);
        obs::observe(obs::Hist::JournalFrameBytes, frame.len() as u64);
        {
            let _append_timer = obs::timer(obs::Hist::JournalAppendNs);
            self.file.write_all(&frame).with_context(|| {
                format!("appending to journal {}", self.path.display())
            })?;
            self.file
                .flush()
                .with_context(|| format!("flushing journal {}", self.path.display()))?;
        }
        self.len += frame.len() as u64;
        if let JournalRecord::SnapshotMark { round, .. } = rec {
            self.marks.push((*round, self.len));
        }
        Ok(self.len)
    }

    /// Truncate back to just past the [`JournalRecord::SnapshotMark`]
    /// for `round` (the snapshot a resume loaded). Returns false if no
    /// such mark is durable — the caller then resets and re-marks.
    pub fn truncate_to_mark(&mut self, round: usize) -> Result<bool> {
        let Some(pos) = self.marks.iter().rposition(|&(r, _)| r == round) else {
            return Ok(false);
        };
        let off = self.marks[pos].1;
        self.file.set_len(off).with_context(|| {
            format!("truncating journal {} to snapshot mark", self.path.display())
        })?;
        self.len = off;
        self.marks.truncate(pos + 1);
        Ok(true)
    }

    /// Drop every record (the no-usable-mark fallback).
    pub fn reset(&mut self) -> Result<()> {
        self.file
            .set_len(0)
            .with_context(|| format!("resetting journal {}", self.path.display()))?;
        self.len = 0;
        self.marks.clear();
        Ok(())
    }

    pub fn len_bytes(&self) -> u64 {
        self.len
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

// -- replay verification -----------------------------------------------------

/// Replay a journal prefix through a scratch [`RoundFsm`] — the exact
/// `begin_round`/`apply`/epoch-fencing machinery that produced it — and
/// check internal consistency: every `RoundStart` must mint the epoch
/// the journal recorded, and every `RoundClose` must agree with the
/// machine's submitted set. A trailing `RoundStart` group without its
/// `RoundClose` is legal (the crash-mid-round case) and left open.
/// Returns the number of fully verified rounds.
pub fn verify_replay(records: &[JournalRecord]) -> Result<usize> {
    let mut fsm = RoundFsm::new();
    let mut queue = EventQueue::new();
    let mut in_round = false;
    let mut rounds = 0usize;
    for (i, rec) in records.iter().enumerate() {
        match rec {
            JournalRecord::RoundStart {
                epoch,
                t0,
                round_cap,
                n_clients,
                clients,
                n_required,
                unconstrained,
                ..
            } => {
                if in_round {
                    bail!("journal record {i}: RoundStart inside an open round");
                }
                if *epoch == 0 {
                    bail!("journal record {i}: RoundStart with epoch 0");
                }
                // the machine mints epoch+1, so seed it one behind
                fsm.restore_epoch(epoch - 1);
                let decision = SelectionDecision {
                    clients: clients.clone(),
                    expected_duration: 0,
                    n_required: *n_required,
                    max_duration: *round_cap,
                    wait: false,
                    unconstrained: *unconstrained,
                };
                queue.clear();
                fsm.begin_round(&decision, *n_clients, *t0, *round_cap, &mut queue)
                    .map_err(|e| anyhow!("journal record {i}: {e}"))?;
                if fsm.epoch() != *epoch {
                    bail!(
                        "journal record {i}: replay minted epoch {} but the \
                         journal recorded {}",
                        fsm.epoch(),
                        epoch
                    );
                }
                fsm.start_training();
                in_round = true;
            }
            JournalRecord::Event { ev, .. } => {
                // journal order = application order; outside a round the
                // machine fences/ignores exactly as the live engine did
                fsm.apply(ev);
            }
            JournalRecord::RoundClose { timed_out, submitted, .. } => {
                if !in_round {
                    bail!("journal record {i}: RoundClose without a RoundStart");
                }
                if fsm.submissions() != submitted.len() {
                    bail!(
                        "journal record {i}: replay saw {} submissions, \
                         RoundClose recorded {}",
                        fsm.submissions(),
                        submitted.len()
                    );
                }
                for &slot in submitted {
                    if !fsm.submitted(slot) {
                        bail!(
                            "journal record {i}: RoundClose lists slot {slot} \
                             but replay never saw its update"
                        );
                    }
                }
                fsm.close(*timed_out);
                fsm.round_end();
                fsm.finish();
                in_round = false;
                rounds += 1;
            }
            JournalRecord::SnapshotMark { .. } => {
                if in_round {
                    bail!("journal record {i}: SnapshotMark inside an open round");
                }
            }
        }
    }
    Ok(rounds)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fedzero_journal_{}_{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_records() -> Vec<JournalRecord> {
        let epoch = 1u64;
        vec![
            JournalRecord::SnapshotMark { round: 0, t: 0 },
            JournalRecord::RoundStart {
                round: 0,
                epoch,
                t0: 3,
                round_cap: 10,
                n_clients: 5,
                clients: vec![2, 0, 4],
                n_required: 2,
                unconstrained: false,
            },
            JournalRecord::Event {
                at: 3,
                ev: ClientEvent::CheckIn { client: 2, epoch },
            },
            JournalRecord::Event {
                at: 3,
                ev: ClientEvent::CheckIn { client: 0, epoch },
            },
            JournalRecord::Event {
                at: 4,
                ev: ClientEvent::Dropout { client: 4, epoch },
            },
            JournalRecord::Event {
                at: 6,
                ev: ClientEvent::UpdateSubmitted { client: 2, epoch },
            },
            JournalRecord::Event {
                at: 7,
                ev: ClientEvent::UpdateSubmitted { client: 0, epoch },
            },
            JournalRecord::RoundClose {
                round: 0,
                timed_out: false,
                submitted: vec![0, 1],
                participants: vec![2, 0],
            },
            JournalRecord::SnapshotMark { round: 1, t: 9 },
        ]
    }

    #[test]
    fn records_roundtrip_through_json() {
        for rec in sample_records() {
            let text = rec.to_json().to_string_compact();
            let parsed = JournalRecord::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(parsed, rec);
        }
        // full-range epoch tokens survive (the hex encoding's reason)
        let rec = JournalRecord::Event {
            at: 1,
            ev: ClientEvent::Timeout { epoch: u64::MAX },
        };
        let text = rec.to_json().to_string_compact();
        assert_eq!(
            JournalRecord::from_json(&Json::parse(&text).unwrap()).unwrap(),
            rec
        );
    }

    #[test]
    fn append_then_open_returns_identical_records() {
        let dir = scratch("roundtrip");
        let path = dir.join("wal.log");
        let recs = sample_records();
        {
            let mut j = Journal::create(&path).unwrap();
            for r in &recs {
                j.append(r).unwrap();
            }
        }
        let (j, loaded) = Journal::open(&path).unwrap();
        assert_eq!(loaded, recs);
        assert_eq!(j.len_bytes(), std::fs::metadata(&path).unwrap().len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Satellite: truncating an in-flight record at EVERY byte offset
    /// still opens cleanly, drops only the torn tail, and replays to
    /// the last durable state.
    #[test]
    fn torn_write_recovery_at_every_byte_offset() {
        let dir = scratch("torn");
        let path = dir.join("wal.log");
        let recs = sample_records();
        let mut j = Journal::create(&path).unwrap();
        let mut prefix_len = 0u64;
        for r in &recs[..recs.len() - 1] {
            prefix_len = j.append(r).unwrap();
        }
        let full_len = j.append(&recs[recs.len() - 1]).unwrap();
        drop(j);
        let full = std::fs::read(&path).unwrap();
        assert_eq!(full.len() as u64, full_len);

        let torn_path = dir.join("torn.log");
        for cut in prefix_len..full_len {
            std::fs::write(&torn_path, &full[..cut as usize]).unwrap();
            let (tj, loaded) = Journal::open(&torn_path).unwrap();
            assert_eq!(
                loaded,
                recs[..recs.len() - 1],
                "cut at byte {cut} of {full_len}"
            );
            assert_eq!(tj.len_bytes(), prefix_len, "cut at byte {cut}");
            drop(tj);
            // the torn tail was physically truncated
            assert_eq!(
                std::fs::metadata(&torn_path).unwrap().len(),
                prefix_len,
                "cut at byte {cut}"
            );
            // the durable prefix still replays cleanly
            assert_eq!(verify_replay(&loaded).unwrap(), 1);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_payload_byte_drops_only_the_tail() {
        let dir = scratch("corrupt");
        let path = dir.join("wal.log");
        let recs = sample_records();
        let mut j = Journal::create(&path).unwrap();
        let mut prefix_len = 0u64;
        for r in &recs[..recs.len() - 1] {
            prefix_len = j.append(r).unwrap();
        }
        j.append(&recs[recs.len() - 1]).unwrap();
        drop(j);
        let mut bytes = std::fs::read(&path).unwrap();
        let flip = prefix_len as usize + 12; // inside the last payload
        bytes[flip] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let (_, loaded) = Journal::open(&path).unwrap();
        assert_eq!(loaded, recs[..recs.len() - 1]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn appends_after_torn_open_continue_the_durable_prefix() {
        let dir = scratch("reappend");
        let path = dir.join("wal.log");
        let recs = sample_records();
        let mut j = Journal::create(&path).unwrap();
        let mut prefix_len = 0u64;
        for r in &recs[..recs.len() - 1] {
            prefix_len = j.append(r).unwrap();
        }
        j.append(&recs[recs.len() - 1]).unwrap();
        drop(j);
        // tear mid-way through the final record
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..(prefix_len as usize + 5)]).unwrap();
        let (mut j, _) = Journal::open(&path).unwrap();
        // re-append the same record: bytes must equal the untorn file
        j.append(&recs[recs.len() - 1]).unwrap();
        drop(j);
        assert_eq!(std::fs::read(&path).unwrap(), full);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncate_to_mark_drops_post_snapshot_records() {
        let dir = scratch("marks");
        let path = dir.join("wal.log");
        let recs = sample_records();
        let mut j = Journal::create(&path).unwrap();
        let mut len_after_first_mark = 0;
        for r in &recs {
            let len = j.append(r).unwrap();
            if matches!(r, JournalRecord::SnapshotMark { round: 0, .. }) {
                len_after_first_mark = len;
            }
        }
        assert!(j.truncate_to_mark(0).unwrap());
        assert_eq!(j.len_bytes(), len_after_first_mark);
        assert!(!j.truncate_to_mark(9).unwrap(), "unknown mark");
        drop(j);
        let (_, loaded) = Journal::open(&path).unwrap();
        assert_eq!(loaded, recs[..1]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verify_replay_accepts_history_and_rejects_tampering() {
        let recs = sample_records();
        assert_eq!(verify_replay(&recs).unwrap(), 1);

        // crash-mid-round: trailing open round group is tolerated
        let mut open_round = recs.clone();
        open_round.truncate(recs.len() - 2); // drop RoundClose + mark
        assert_eq!(verify_replay(&open_round).unwrap(), 0);

        // tamper: RoundClose claims a slot that never submitted
        let mut tampered = recs.clone();
        if let JournalRecord::RoundClose { submitted, .. } = &mut tampered[7] {
            submitted.push(2);
        }
        assert!(verify_replay(&tampered).is_err());

        // tamper: drop an update event the close depends on
        let mut missing = recs.clone();
        missing.remove(6);
        assert!(verify_replay(&missing).is_err());

        // tamper: journal claims a different epoch than replay mints
        let mut wrong_epoch = recs;
        if let JournalRecord::RoundStart { epoch, .. } = &mut wrong_epoch[1] {
            *epoch = 3;
        }
        // events still carry epoch 1 → close sees zero submissions
        assert!(verify_replay(&wrong_epoch).is_err());
    }

    #[test]
    fn stale_events_outside_rounds_replay_harmlessly() {
        // a delayed update surfacing between rounds (fsm Idle) is fenced
        // on replay exactly as it was live
        let epoch = 1u64;
        let recs = vec![
            JournalRecord::RoundStart {
                round: 0,
                epoch,
                t0: 0,
                round_cap: 5,
                n_clients: 3,
                clients: vec![0, 1],
                n_required: 1,
                unconstrained: false,
            },
            JournalRecord::Event {
                at: 2,
                ev: ClientEvent::UpdateSubmitted { client: 0, epoch },
            },
            JournalRecord::RoundClose {
                round: 0,
                timed_out: false,
                submitted: vec![0],
                participants: vec![0],
            },
            // late straggler from the closed round, applied while Idle
            JournalRecord::Event {
                at: 9,
                ev: ClientEvent::UpdateSubmitted { client: 1, epoch },
            },
        ];
        assert_eq!(verify_replay(&recs).unwrap(), 1);
    }
}
