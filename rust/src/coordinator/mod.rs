//! Experiment coordinator — the leader process that wires scenario,
//! dataset, backend, strategy and simulator together and runs one
//! experiment end to end. Every `repro` CLI subcommand and example builds
//! on this.
//!
//! The round lifecycle itself lives in [`fsm`] (the event-driven state
//! machine the engine executes rounds through) and [`events`] (the
//! deterministic client-event queue feeding it). [`journal`] makes that
//! lifecycle durable: a write-ahead log of decisions and events plus
//! snapshot marks, giving the engine its crash-fault `resume_from` path.

pub mod events;
pub mod fsm;
pub mod journal;

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::client::ModelKind;
use crate::config::{BuiltScenario, Scenario};
use crate::data::{dirichlet_partition, imbalanced_partition, Partition, SynthConfig, SynthDataset};
use crate::fl::{MockBackend, TrainBackend, XlaBackend};
use crate::scenario::{build_env, EnvConfig, EnvSpec};
use crate::metrics::MetricsLog;
use crate::runtime::ModelRuntime;
use crate::selection::adaptive::ChurnAware;
use crate::selection::baselines::{Baseline, UpperBound};
use crate::selection::fedzero::{FedZero, SolverKind};
use crate::selection::semisync::SemiSync;
use crate::selection::Strategy;
use crate::sim::{DurableConfig, SimConfig, Simulation};
use crate::trace::forecast::ErrorLevel;
use crate::util::rng::Rng;

/// All strategies evaluated in the paper (§5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    FedZero,
    FedZeroExact,
    Random,
    RandomOver,
    RandomFc,
    Oort,
    OortOver,
    OortFc,
    UpperBound,
    /// §7 extension: FedZero selection + fixed-deadline aggregation
    SemiSync,
    /// §7 extension: FedZero with churn-aware reactive over-selection
    /// (`selection::adaptive::ChurnAware`)
    FedZeroCa,
    /// §7 extension: SemiSync with churn-aware reactive over-selection
    SemiSyncCa,
}

impl StrategyKind {
    pub const ALL: [StrategyKind; 8] = [
        StrategyKind::UpperBound,
        StrategyKind::Random,
        StrategyKind::RandomOver,
        StrategyKind::RandomFc,
        StrategyKind::Oort,
        StrategyKind::OortOver,
        StrategyKind::OortFc,
        StrategyKind::FedZero,
    ];

    pub fn build(self) -> Box<dyn Strategy> {
        match self {
            StrategyKind::FedZero => {
                Box::new(FedZero::new(SolverKind::Greedy))
            }
            StrategyKind::FedZeroExact => {
                Box::new(FedZero::new(SolverKind::Exact))
            }
            StrategyKind::Random => Box::new(Baseline::random()),
            StrategyKind::RandomOver => Box::new(Baseline::random_over()),
            StrategyKind::RandomFc => Box::new(Baseline::random_fc()),
            StrategyKind::Oort => Box::new(Baseline::oort()),
            StrategyKind::OortOver => Box::new(Baseline::oort_over()),
            StrategyKind::OortFc => Box::new(Baseline::oort_fc()),
            StrategyKind::UpperBound => Box::new(UpperBound),
            StrategyKind::SemiSync => Box::new(SemiSync::new(
                FedZero::new(SolverKind::Greedy),
                15,
            )),
            StrategyKind::FedZeroCa => Box::new(ChurnAware::new(
                FedZero::new(SolverKind::Greedy),
                "FedZero ca",
                true,
            )),
            StrategyKind::SemiSyncCa => Box::new(ChurnAware::new(
                SemiSync::new(FedZero::new(SolverKind::Greedy), 15),
                "SemiSync ca",
                false,
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::FedZero => "FedZero",
            StrategyKind::FedZeroExact => "FedZero(exact)",
            StrategyKind::Random => "Random",
            StrategyKind::RandomOver => "Random 1.3n",
            StrategyKind::RandomFc => "Random fc",
            StrategyKind::Oort => "Oort",
            StrategyKind::OortOver => "Oort 1.3n",
            StrategyKind::OortFc => "Oort fc",
            StrategyKind::UpperBound => "Upper bound",
            StrategyKind::SemiSync => "SemiSync",
            StrategyKind::FedZeroCa => "FedZero ca",
            StrategyKind::SemiSyncCa => "SemiSync ca",
        }
    }

    pub fn parse(s: &str) -> Result<StrategyKind> {
        Ok(match s.to_ascii_lowercase().replace([' ', '-', '_'], "").as_str() {
            "fedzero" => StrategyKind::FedZero,
            "fedzeroexact" => StrategyKind::FedZeroExact,
            "random" => StrategyKind::Random,
            "random1.3n" | "randomover" => StrategyKind::RandomOver,
            "randomfc" => StrategyKind::RandomFc,
            "oort" => StrategyKind::Oort,
            "oort1.3n" | "oortover" => StrategyKind::OortOver,
            "oortfc" => StrategyKind::OortFc,
            "upperbound" | "upper" => StrategyKind::UpperBound,
            "semisync" => StrategyKind::SemiSync,
            "fedzeroca" => StrategyKind::FedZeroCa,
            "semisyncca" => StrategyKind::SemiSyncCa,
            other => return Err(anyhow!("unknown strategy {other}")),
        })
    }
}

/// One experiment = scenario × dataset/model × strategy (× error model).
///
/// Environment construction is spec-driven ([`crate::scenario`]): the
/// `scenario` enum picks a builtin [`EnvSpec`] (bit-identical to the
/// legacy `config::build` output), and `env` overrides it with an
/// arbitrary declarative environment — custom sites, batteries, device
/// mixes, churn — without touching the rest of the pipeline.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    /// model/dataset preset: tiny | vision | imagenet | seq | speech
    pub preset: String,
    pub scenario: Scenario,
    /// declarative environment override; None = builtin spec for
    /// `scenario`
    pub env: Option<EnvSpec>,
    /// Dirichlet α override for label-skew partitions (None = the
    /// preset's paper value) — the campaign runner's non-IID sweep axis
    pub partition_alpha: Option<f64>,
    pub strategy: StrategyKind,
    pub days: usize,
    pub n_clients: usize,
    pub n_per_round: usize,
    pub d_max: usize,
    pub seed: u64,
    pub energy_error: ErrorLevel,
    pub load_error: ErrorLevel,
    pub unlimited_domain: Option<usize>,
    /// scales the synthetic dataset size (1.0 = default scale)
    pub dataset_scale: f64,
    /// use the deterministic mock backend instead of PJRT (fast smoke runs)
    pub use_mock: bool,
    pub lr: f32,
    pub mu: f32,
    pub eval_every: usize,
    /// cap eval to this many test samples (0 = all)
    pub eval_subset: usize,
    pub artifact_dir: PathBuf,
    /// durable-coordinator checkpoint directory: when set the run keeps a
    /// write-ahead journal + periodic snapshots there
    /// ([`crate::sim::DurableConfig`]), and `resume` continues a killed
    /// run from it bit-identically
    pub checkpoint_dir: Option<PathBuf>,
    /// snapshot cadence in rounds (only read when `checkpoint_dir` is
    /// set). The cadence shapes the journal byte stream, so a resumed run
    /// must use the same value as the original.
    pub snapshot_every: usize,
    /// resume from `checkpoint_dir` instead of starting fresh
    pub resume: bool,
}

impl Default for ExperimentSpec {
    fn default() -> Self {
        ExperimentSpec {
            preset: "tiny".into(),
            scenario: Scenario::Global,
            env: None,
            partition_alpha: None,
            strategy: StrategyKind::FedZero,
            days: 7,
            n_clients: 100,
            n_per_round: 10,
            d_max: 60,
            seed: 0,
            energy_error: ErrorLevel::Realistic,
            load_error: ErrorLevel::Realistic,
            unlimited_domain: None,
            dataset_scale: 1.0,
            use_mock: false,
            lr: 0.05,
            mu: 0.01,
            eval_every: 5,
            eval_subset: 512,
            artifact_dir: PathBuf::from("artifacts"),
            checkpoint_dir: None,
            snapshot_every: 5,
            resume: false,
        }
    }
}

/// Result bundle for reporting.
pub struct RunReport {
    pub spec_name: String,
    pub strategy: StrategyKind,
    pub metrics: MetricsLog,
    pub client_domains: Vec<usize>,
    pub n_domains: usize,
    pub select_time_ms: f64,
    pub steps_executed: u64,
    pub wallclock_s: f64,
}

/// Dataset spec per preset: (classes, base train size, base test size,
/// partition kind, within-class noise). Noise is calibrated so the MLP's
/// achievable accuracy sits well below 100% and convergence takes many
/// rounds — mirroring the role of the paper's real datasets, where the
/// interesting signal is *when* each strategy reaches the target, not
/// whether it saturates.
fn dataset_plan(preset: &str) -> (usize, usize, usize, &'static str, f64) {
    match preset {
        "tiny" => (8, 24_000, 2_400, "dirichlet", 2.6),
        "vision" => (20, 30_000, 3_000, "dirichlet", 2.4),
        "imagenet" => (40, 32_000, 3_000, "dirichlet", 2.6),
        "seq" => (32, 40_000, 2_500, "imbalanced", 2.2),
        "speech" => (30, 24_000, 2_400, "speaker", 2.0),
        other => panic!("unknown preset {other}"),
    }
}

/// Build the dataset + partition for a preset (dims from the manifest when
/// PJRT-backed; a small fixed dim for mocks).
pub fn build_dataset(
    spec: &ExperimentSpec,
    input_dim: usize,
) -> (SynthDataset, Partition) {
    let (classes, base_train, base_test, part_kind, noise) =
        dataset_plan(&spec.preset);
    let n_train =
        ((base_train as f64 * spec.dataset_scale) as usize).max(spec.n_clients);
    let n_test = ((base_test as f64 * spec.dataset_scale) as usize).max(64);
    let mut cfg = SynthConfig::new(input_dim, classes, n_train, n_test);
    cfg.noise = noise;
    cfg.seed = spec.seed ^ 0xDA7A;
    let ds = SynthDataset::generate(&cfg);
    let mut rng = Rng::new(spec.seed ^ 0x9A97);
    let partition = match part_kind {
        "dirichlet" => {
            let alpha = spec.partition_alpha.unwrap_or(0.5);
            dirichlet_partition(&ds.train_y, spec.n_clients, alpha, &mut rng)
        }
        "imbalanced" => {
            // paper's Shakespeare shape (min 730 / max 27950) at our scale
            let lo = (n_train / spec.n_clients / 8).max(5);
            let hi = n_train / 3;
            imbalanced_partition(&ds.train_y, spec.n_clients, (lo, hi), &mut rng)
        }
        "speaker" => {
            // speakers assigned randomly -> milder skew
            let alpha = spec.partition_alpha.unwrap_or(2.0);
            dirichlet_partition(&ds.train_y, spec.n_clients, alpha, &mut rng)
        }
        other => panic!("unknown partition kind {other}"),
    };
    (ds, partition)
}

/// The experiment's environment spec: an explicit override, or the
/// builtin spec matching the legacy scenario enum.
fn env_spec(spec: &ExperimentSpec) -> EnvSpec {
    spec.env.clone().unwrap_or_else(|| EnvSpec::builtin(spec.scenario))
}

fn env_cfg(spec: &ExperimentSpec) -> EnvConfig {
    EnvConfig {
        n_clients: spec.n_clients,
        days: spec.days,
        step_minutes: 1.0,
        energy_error: spec.energy_error,
        load_error: spec.load_error,
        unlimited_domain: spec.unlimited_domain,
        seed: spec.seed,
    }
}

fn run_with_backend<B: TrainBackend>(
    spec: &ExperimentSpec,
    built: BuiltScenario,
    backend: &B,
) -> Result<RunReport> {
    let mut strategy = spec.strategy.build();
    let sim_cfg = SimConfig {
        step_minutes: 1.0,
        horizon: built.horizon,
        n_per_round: spec.n_per_round,
        d_max: spec.d_max,
        eval_every: spec.eval_every,
        seed: spec.seed,
    };
    let client_domains = built.client_domains();
    let n_domains = built.domains.len();
    let t0 = Instant::now();
    let mut sim = Simulation::new(
        sim_cfg,
        built.clients,
        built.domains,
        built.load_actual,
        built.load_fc,
        spec.load_error,
        backend,
        strategy.as_mut(),
    );
    sim.outages = built.outages;
    sim.chaos = env_spec(spec).chaos;
    match &spec.checkpoint_dir {
        Some(dir) => {
            sim.durable = Some(DurableConfig {
                dir: dir.clone(),
                snapshot_every: spec.snapshot_every,
            });
            if spec.resume {
                sim.resume_from(dir)?;
            } else {
                sim.run()?;
            }
        }
        None => sim.run()?,
    }
    let wallclock_s = t0.elapsed().as_secs_f64();
    let select_time_ms = sim.select_time.as_secs_f64() * 1e3;
    // deterministic per-client reduction over the engine-owned train
    // states (there is no backend-side counter any more)
    let steps_executed = sim.steps_executed();
    let metrics = std::mem::take(&mut sim.metrics);
    drop(sim);
    Ok(RunReport {
        spec_name: format!(
            "{}/{}/{}",
            spec.preset,
            spec.scenario.name(),
            spec.strategy.name()
        ),
        strategy: spec.strategy,
        metrics,
        client_domains,
        n_domains,
        select_time_ms,
        steps_executed,
        wallclock_s,
    })
}

/// Run a mock-backed simulation over an already-built environment —
/// the campaign runner's entry point (it memoizes [`BuiltScenario`]s
/// across cells) and the mock arm of [`run_experiment`]. The backend
/// wiring here defines the deterministic mock fixture: input dim 16,
/// batch 10, noise 0.3, seeded by the spec.
pub fn run_built_mock(spec: &ExperimentSpec, built: BuiltScenario) -> Result<RunReport> {
    let backend = MockBackend::new(spec.n_clients, 16, 0.3, spec.seed);
    run_with_backend(spec, built, &backend)
}

/// The mock fixture's dataset partition for a spec (input dim 16 —
/// the same constant [`build_mock_env`] uses). Split out so the
/// campaign runner can memoize the synthetic dataset separately from
/// the environment build: the partition depends only on
/// (preset, seed, α, n_clients, dataset_scale), not on the env axes.
pub fn build_mock_partition(spec: &ExperimentSpec) -> Partition {
    build_dataset(spec, 16).1
}

/// [`build_mock_env`] with a caller-supplied (possibly memoized)
/// partition. `env_spec`/`env_cfg` are private to this module, so the
/// env build over an external partition has to live here too.
pub fn build_mock_env_with(
    spec: &ExperimentSpec,
    partition: &Partition,
) -> Result<BuiltScenario> {
    let model = ModelKind::from_preset(&spec.preset);
    build_env(&env_spec(spec), &env_cfg(spec), model, 10, partition)
}

/// Build the mock fixture's environment for a spec (partition at input
/// dim 16, batch size 10, spec-driven env). ONE definition shared by
/// [`run_experiment`]'s mock arm and the campaign runner, so the two
/// cannot drift apart on the fixture constants.
pub fn build_mock_env(spec: &ExperimentSpec) -> Result<BuiltScenario> {
    build_mock_env_with(spec, &build_mock_partition(spec))
}

/// Does this preset's partition scheme read `partition_alpha`? The
/// Shakespeare-shaped "seq" preset uses the log-normal imbalanced
/// partition, which has no α knob — a campaign sweeping α over it would
/// silently produce duplicate cells (the campaign runner rejects that).
pub fn preset_uses_alpha(preset: &str) -> bool {
    dataset_plan(preset).3 != "imbalanced"
}

/// Run one experiment end to end. The environment always comes from the
/// declarative builder ([`crate::scenario::build_env`]); the builtin
/// specs reproduce the legacy `config::build` output bit for bit
/// (`builtin_spec_path_matches_legacy_config_build` below).
pub fn run_experiment(spec: &ExperimentSpec) -> Result<RunReport> {
    let model = ModelKind::from_preset(&spec.preset);
    if spec.use_mock {
        let built = build_mock_env(spec)?;
        run_built_mock(spec, built)
    } else {
        let runtime = ModelRuntime::load(&spec.artifact_dir, &spec.preset)?;
        let (ds, partition) =
            build_dataset(spec, runtime.manifest.input_dim);
        let batch = runtime.manifest.batch_size;
        let built =
            build_env(&env_spec(spec), &env_cfg(spec), model, batch, &partition)?;
        let mut backend = XlaBackend::new(
            runtime,
            ds,
            &partition,
            spec.lr,
            spec.mu,
            spec.seed,
        )?;
        backend.eval_subset = spec.eval_subset;
        run_with_backend(spec, built, &backend)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for k in StrategyKind::ALL {
            assert_eq!(StrategyKind::parse(k.name()).unwrap(), k);
        }
        for k in [
            StrategyKind::SemiSync,
            StrategyKind::FedZeroCa,
            StrategyKind::SemiSyncCa,
        ] {
            assert_eq!(StrategyKind::parse(k.name()).unwrap(), k);
        }
        assert!(StrategyKind::parse("bogus").is_err());
    }

    #[test]
    fn mock_experiment_runs_all_strategies() {
        for strategy in [
            StrategyKind::FedZero,
            StrategyKind::Random,
            StrategyKind::OortOver,
            StrategyKind::UpperBound,
        ] {
            let spec = ExperimentSpec {
                use_mock: true,
                days: 1,
                n_clients: 20,
                n_per_round: 4,
                d_max: 30,
                strategy,
                preset: "tiny".into(),
                dataset_scale: 0.2,
                ..Default::default()
            };
            let report = run_experiment(&spec).unwrap();
            assert!(
                !report.metrics.rounds.is_empty(),
                "{} did no rounds",
                strategy.name()
            );
            assert!(report.metrics.best_accuracy() > 0.0);
        }
    }

    #[test]
    fn dataset_plans_differ_by_preset() {
        let spec = ExperimentSpec {
            preset: "seq".into(),
            n_clients: 20,
            dataset_scale: 0.3,
            ..Default::default()
        };
        let (_, part) = build_dataset(&spec, 16);
        let sizes: Vec<f64> =
            part.sizes().iter().map(|&s| s as f64).collect();
        // Shakespeare-like: heavy imbalance
        assert!(
            crate::util::stats::std(&sizes)
                > 0.4 * crate::util::stats::mean(&sizes)
        );

        let spec2 = ExperimentSpec {
            preset: "vision".into(),
            n_clients: 20,
            dataset_scale: 0.3,
            ..spec
        };
        let (_, part2) = build_dataset(&spec2, 16);
        assert!(part2.is_disjoint());
    }

    /// The ISSUE-5 acceptance gate: the spec-driven coordinator path
    /// reproduces the pre-refactor `config::build` path bit for bit —
    /// `MetricsLog` equality (f64 energies/losses included), same step
    /// totals — for both paper scenarios.
    #[test]
    fn builtin_spec_path_matches_legacy_config_build() {
        for scenario in [Scenario::Global, Scenario::Colocated] {
            let spec = ExperimentSpec {
                use_mock: true,
                days: 1,
                n_clients: 20,
                n_per_round: 4,
                d_max: 30,
                scenario,
                preset: "tiny".into(),
                dataset_scale: 0.2,
                seed: 3,
                ..Default::default()
            };
            // new path: run_experiment -> scenario::build_env(builtin)
            let fresh = run_experiment(&spec).unwrap();
            // legacy path: the retained enum-driven builder, wired into
            // the identical backend/sim fixture
            let model = ModelKind::from_preset(&spec.preset);
            let (_, partition) = build_dataset(&spec, 16);
            let legacy_built = crate::config::build(
                &crate::config::ScenarioConfig {
                    scenario: spec.scenario,
                    n_clients: spec.n_clients,
                    days: spec.days,
                    step_minutes: 1.0,
                    domain_capacity_w: 800.0,
                    energy_error: spec.energy_error,
                    load_error: spec.load_error,
                    unlimited_domain: spec.unlimited_domain,
                    seed: spec.seed,
                },
                model,
                10,
                &partition,
            );
            let legacy = run_built_mock(&spec, legacy_built).unwrap();
            assert_eq!(
                fresh.metrics, legacy.metrics,
                "{scenario:?}: spec-driven metrics diverged from legacy"
            );
            assert_eq!(fresh.steps_executed, legacy.steps_executed);
            assert_eq!(fresh.client_domains, legacy.client_domains);
        }
    }

    #[test]
    fn env_override_reaches_the_simulation() {
        // a custom 2-site environment flows through the whole pipeline
        let spec = ExperimentSpec {
            use_mock: true,
            days: 1,
            n_clients: 12,
            n_per_round: 3,
            d_max: 30,
            preset: "tiny".into(),
            dataset_scale: 0.2,
            env: Some(EnvSpec {
                sites: crate::scenario::SiteSet::Custom(vec![
                    crate::trace::solar::Site::new("a", 10.0, 0.0, 0.1),
                    crate::trace::solar::Site::new("b", -10.0, 12.0, 0.1),
                ]),
                ..EnvSpec::global()
            }),
            ..Default::default()
        };
        let report = run_experiment(&spec).unwrap();
        assert_eq!(report.n_domains, 2);
        assert!(report.client_domains.iter().all(|&d| d < 2));
        assert!(!report.metrics.rounds.is_empty());
    }

    /// The CLI-facing plumbing of the durable coordinator: a spec with
    /// `checkpoint_dir` + a certain crash chaos dies with [`CrashFault`],
    /// and the same spec re-run with `resume` finishes with metrics
    /// bit-identical to a run that never crashed. (The engine- and
    /// campaign-level equivalents live in `sim::engine` /
    /// `scenario::campaign`; this one guards the `ExperimentSpec` path.)
    #[test]
    fn checkpointed_experiment_resumes_bit_identically() {
        let dir = std::env::temp_dir()
            .join(format!("fedzero_coord_{}_ckpt", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = |crash: f64, ckpt: bool, resume: bool| ExperimentSpec {
            use_mock: true,
            days: 1,
            n_clients: 20,
            n_per_round: 4,
            d_max: 30,
            preset: "tiny".into(),
            dataset_scale: 0.2,
            seed: 11,
            env: Some(EnvSpec {
                chaos: Some(crate::sim::ChaosSpec {
                    crash_prob: crash,
                    ..Default::default()
                }),
                ..EnvSpec::global()
            }),
            checkpoint_dir: ckpt.then(|| dir.clone()),
            snapshot_every: 3,
            resume,
            ..Default::default()
        };
        let reference = run_experiment(&spec(0.0, false, false)).unwrap();
        let err = run_experiment(&spec(1.0, true, false)).unwrap_err();
        assert!(
            err.downcast_ref::<crate::sim::CrashFault>().is_some(),
            "expected CrashFault, got {err:#}"
        );
        // resume ignores the armed crash (a fault fires once per process
        // life) and must land exactly where the uninterrupted run did
        let resumed = run_experiment(&spec(1.0, true, true)).unwrap();
        assert_eq!(reference.metrics, resumed.metrics);
        assert_eq!(reference.steps_executed, resumed.steps_executed);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unlimited_domain_spec_runs() {
        let spec = ExperimentSpec {
            use_mock: true,
            days: 1,
            n_clients: 20,
            n_per_round: 4,
            unlimited_domain: Some(0),
            ..Default::default()
        };
        let report = run_experiment(&spec).unwrap();
        assert!(!report.metrics.rounds.is_empty());
    }
}
