//! Event-driven round state machine: the coordinator-side lifecycle of
//! one federated round, driven by the deterministic event queue in
//! [`super::events`].
//!
//! # State diagram
//!
//! ```text
//!            begin_round(decision)         start_training()
//!   Idle ───────────────────────▶ Selecting ───────────────▶ Training
//!    ▲      (validates; mints a                                  │
//!    │       fresh epoch token)                                  │ close()
//!    │                                                           │  · quorum (done ≥ n_required)
//!    │ finish()                              round_end()         ▼  · Timeout event · horizon
//!    └────────────── RoundEnd ◀──────────────────────── Aggregating
//! ```
//!
//! While `Training`, the engine pops due events each timestep and feeds
//! them through [`RoundFsm::apply`]:
//!
//! * `CheckIn` — a selected client acknowledges the assignment.
//! * `Dropout` / `Rejoin` — liveness bookkeeping. Offline-ness is a
//!   **depth counter** per slot, so overlapping windows from
//!   independent sources (churn + chaos) compose: a client is online
//!   iff its depth is zero.
//! * `UpdateSubmitted` — counts toward the quorum iff its epoch token
//!   matches the current round AND the round is still training;
//!   anything else is reported as [`EventOutcome::StaleUpdate`] so the
//!   engine can meter it as waste instead of silently aggregating it.
//! * `Timeout` — fires [`EventOutcome::TimeoutFired`] iff current; the
//!   engine then closes the round gracefully with whatever
//!   participants met `m_min` (possibly none — an empty round degrades
//!   to a no-op aggregation, never an error).
//!
//! # Epoch-token invariant
//!
//! `begin_round` mints `epoch + 1`; every event scheduled on behalf of
//! that round carries the token. An event whose token differs from the
//! machine's current epoch can NEVER mutate round state — it is either
//! ignored (liveness, timeouts) or surfaced as a stale update. Because
//! the event queue persists across rounds, this is the only thing
//! standing between a delayed update from round `r` and the aggregate
//! of round `r + 1`; the invariant is load-bearing and tested.
//!
//! # Determinism
//!
//! The machine itself is pure bookkeeping — no RNG, no clock. All
//! nondeterminism lives in the event *sources* (churn, chaos), which
//! are seeded pure functions; event *ordering* is fixed by the queue's
//! `(at, seq)` order. Replaying the same decisions and events yields
//! bit-identical state, which is what the legacy-loop-vs-FSM and
//! two-run chaos gates in `sim::engine` / `benches/chaos.rs` assert.

use std::collections::HashMap;
use std::fmt;

use crate::selection::SelectionDecision;

use super::events::{ClientEvent, EventQueue};

/// Lifecycle phase of the current round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundPhase {
    /// No round in flight; stale updates arriving now are rejected.
    Idle,
    /// A decision has been validated; clients are being checked in.
    Selecting,
    /// The round is executing; events mutate liveness and quorum.
    Training,
    /// The round has closed; submitted updates are being aggregated.
    Aggregating,
    /// Bookkeeping (metrics, strategy hooks) for the finished round.
    RoundEnd,
}

/// A malformed [`SelectionDecision`] caught at the FSM boundary —
/// returned as a structured error (and metered) instead of the
/// historical `panic!` inside `execute_round`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecisionError {
    /// The decision lists the same client more than once.
    DuplicateClient { client: usize },
    /// The decision references a client id outside the population.
    UnknownClient { client: usize, n_clients: usize },
}

impl fmt::Display for DecisionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            DecisionError::DuplicateClient { client } => write!(
                f,
                "rejected SelectionDecision: client {client} is listed more than once"
            ),
            DecisionError::UnknownClient { client, n_clients } => write!(
                f,
                "rejected SelectionDecision: client {client} is out of range \
                 (population has {n_clients} clients)"
            ),
        }
    }
}

impl std::error::Error for DecisionError {}

/// Validate a decision against the population before any round state
/// is touched. Empty decisions are valid (they degrade to a no-op
/// round), duplicates and out-of-range ids are not.
pub fn validate_decision(
    decision: &SelectionDecision,
    n_clients: usize,
) -> Result<(), DecisionError> {
    let mut seen = vec![false; n_clients];
    for &c in &decision.clients {
        if c >= n_clients {
            return Err(DecisionError::UnknownClient { client: c, n_clients });
        }
        if seen[c] {
            return Err(DecisionError::DuplicateClient { client: c });
        }
        seen[c] = true;
    }
    Ok(())
}

/// What the engine must do in response to one applied event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventOutcome {
    /// Round state was updated; nothing further to do.
    Accepted,
    /// An update with a stale epoch token (or arriving outside a
    /// training round) was rejected — meter it as waste.
    StaleUpdate,
    /// The current round's deadline expired — close the round now.
    TimeoutFired,
    /// Stale liveness event or a client not in this round; no-op.
    Ignored,
}

/// The per-round state machine. One instance lives on the simulation
/// for its whole run — the epoch counter is monotone across rounds;
/// per-slot state is rebuilt by each `begin_round`.
#[derive(Debug)]
pub struct RoundFsm {
    phase: RoundPhase,
    epoch: u64,
    /// client id → slot index within the current round
    slot_of: HashMap<usize, usize>,
    checked_in: Vec<bool>,
    /// offline depth per slot (0 = online); a counter so overlapping
    /// churn + chaos windows compose correctly
    offline_depth: Vec<u32>,
    submitted: Vec<bool>,
    done: usize,
    n_required: usize,
    timed_out: bool,
    /// slot index → domain-shard group (index into the round's sorted
    /// distinct-domain list — the hierarchical aggregator's canonical
    /// group order); empty when no domains were assigned
    shard_group_of_slot: Vec<usize>,
    /// per-group count of slots still owing an update; a group hitting
    /// zero means its domain sub-aggregator could reduce its shard now
    shard_pending: Vec<usize>,
    shards_complete: usize,
}

impl Default for RoundFsm {
    fn default() -> Self {
        Self::new()
    }
}

impl RoundFsm {
    pub fn new() -> Self {
        RoundFsm {
            phase: RoundPhase::Idle,
            epoch: 0,
            slot_of: HashMap::new(),
            checked_in: Vec::new(),
            offline_depth: Vec::new(),
            submitted: Vec::new(),
            done: 0,
            n_required: 0,
            timed_out: false,
            shard_group_of_slot: Vec::new(),
            shard_pending: Vec::new(),
            shards_complete: 0,
        }
    }

    pub fn phase(&self) -> RoundPhase {
        self.phase
    }

    /// The current round's epoch token (monotone across rounds).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Restore the monotone epoch counter from a checkpoint. Only legal
    /// while `Idle` (between rounds — the only phase a snapshot is ever
    /// taken in); the next `begin_round` mints `epoch + 1`, so after a
    /// resume the re-executed round reuses the exact token the
    /// interrupted run minted, and every stale event journaled or queued
    /// before the crash stays fenced identically.
    pub fn restore_epoch(&mut self, epoch: u64) {
        debug_assert_eq!(
            self.phase,
            RoundPhase::Idle,
            "restore_epoch from {:?}",
            self.phase
        );
        self.epoch = epoch;
    }

    /// `Idle → Selecting`: validate the decision, mint a fresh epoch,
    /// initialise per-slot state, and schedule the ceremonial
    /// `CheckIn` events plus the round's `Timeout` at `t0 + cap`.
    pub fn begin_round(
        &mut self,
        decision: &SelectionDecision,
        n_clients: usize,
        t0: usize,
        round_cap: usize,
        queue: &mut EventQueue,
    ) -> Result<(), DecisionError> {
        debug_assert_eq!(self.phase, RoundPhase::Idle, "begin_round from {:?}", self.phase);
        validate_decision(decision, n_clients)?;
        self.epoch += 1;
        let k = decision.clients.len();
        self.phase = RoundPhase::Selecting;
        self.slot_of.clear();
        for (s, &c) in decision.clients.iter().enumerate() {
            self.slot_of.insert(c, s);
            queue.push(t0, ClientEvent::CheckIn { client: c, epoch: self.epoch });
        }
        self.checked_in = vec![false; k];
        self.offline_depth = vec![0; k];
        self.submitted = vec![false; k];
        self.done = 0;
        self.n_required = decision.n_required;
        self.timed_out = false;
        self.shard_group_of_slot.clear();
        self.shard_pending.clear();
        self.shards_complete = 0;
        queue.push(t0 + round_cap, ClientEvent::Timeout { epoch: self.epoch });
        Ok(())
    }

    /// Declare each slot's energy domain so the machine can track
    /// domain-shard completion: a shard is complete the moment its last
    /// in-epoch `UpdateSubmitted` lands — the hook for eager per-domain
    /// sub-aggregation (`fl::tree`), where a sub-aggregator reduces its
    /// shard without barriering on the whole round. Groups are indexed
    /// by ascending distinct domain id, matching the tree's canonical
    /// composition order. Optional: without a call, submission tracking
    /// behaves exactly as before.
    pub fn assign_domains(&mut self, domain_of_slot: &[usize]) {
        debug_assert_eq!(self.phase, RoundPhase::Selecting);
        debug_assert_eq!(domain_of_slot.len(), self.submitted.len());
        let mut doms: Vec<usize> = domain_of_slot.to_vec();
        doms.sort_unstable();
        doms.dedup();
        self.shard_pending.clear();
        self.shard_pending.resize(doms.len(), 0);
        self.shard_group_of_slot.clear();
        for &d in domain_of_slot {
            let g = doms.binary_search(&d).expect("domain in dedup list");
            self.shard_group_of_slot.push(g);
            self.shard_pending[g] += 1;
        }
        self.shards_complete = 0;
    }

    /// Record an offline window already open at round start (the event
    /// queue only carries transitions *inside* the round span).
    pub fn add_initial_offline(&mut self, slot: usize) {
        self.offline_depth[slot] += 1;
    }

    /// `Selecting → Training`.
    pub fn start_training(&mut self) {
        debug_assert_eq!(self.phase, RoundPhase::Selecting);
        self.phase = RoundPhase::Training;
    }

    /// Feed one event through the machine. Epoch fencing happens here:
    /// stale tokens never mutate state.
    pub fn apply(&mut self, ev: &ClientEvent) -> EventOutcome {
        let current = ev.epoch() == self.epoch;
        match *ev {
            ClientEvent::CheckIn { client, .. } => {
                if current
                    && matches!(self.phase, RoundPhase::Selecting | RoundPhase::Training)
                {
                    if let Some(&s) = self.slot_of.get(&client) {
                        self.checked_in[s] = true;
                        return EventOutcome::Accepted;
                    }
                }
                EventOutcome::Ignored
            }
            ClientEvent::Dropout { client, .. } => {
                if current && self.phase == RoundPhase::Training {
                    if let Some(&s) = self.slot_of.get(&client) {
                        self.offline_depth[s] += 1;
                        return EventOutcome::Accepted;
                    }
                }
                EventOutcome::Ignored
            }
            ClientEvent::Rejoin { client, .. } => {
                if current && self.phase == RoundPhase::Training {
                    if let Some(&s) = self.slot_of.get(&client) {
                        self.offline_depth[s] = self.offline_depth[s].saturating_sub(1);
                        return EventOutcome::Accepted;
                    }
                }
                EventOutcome::Ignored
            }
            ClientEvent::UpdateSubmitted { client, .. } => {
                if current && self.phase == RoundPhase::Training {
                    if let Some(&s) = self.slot_of.get(&client) {
                        if !self.submitted[s] {
                            self.submitted[s] = true;
                            self.done += 1;
                            // domain-shard accounting (no-op unless
                            // `assign_domains` declared groups)
                            if let Some(&g) = self.shard_group_of_slot.get(s) {
                                self.shard_pending[g] -= 1;
                                if self.shard_pending[g] == 0 {
                                    self.shards_complete += 1;
                                }
                            }
                            return EventOutcome::Accepted;
                        }
                    }
                }
                // stale token, closed round, unknown client, or double
                // submission — all rejected, all metered
                EventOutcome::StaleUpdate
            }
            ClientEvent::Timeout { .. } => {
                if current && self.phase == RoundPhase::Training {
                    EventOutcome::TimeoutFired
                } else {
                    EventOutcome::Ignored
                }
            }
        }
    }

    /// Is the client in this round's slot `slot` currently online?
    pub fn online(&self, slot: usize) -> bool {
        self.offline_depth[slot] == 0
    }

    pub fn checked_in(&self, slot: usize) -> bool {
        self.checked_in[slot]
    }

    /// Has slot `slot` delivered its (epoch-current) update?
    pub fn submitted(&self, slot: usize) -> bool {
        self.submitted[slot]
    }

    /// Updates accepted so far this round.
    pub fn submissions(&self) -> usize {
        self.done
    }

    /// Has the round met its quorum (`done ≥ n_required`)?
    pub fn quorum(&self) -> bool {
        self.done >= self.n_required
    }

    /// Did this round close on its deadline rather than its quorum?
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }

    /// Domain-shard groups declared by `assign_domains` (0 if unused).
    pub fn shard_groups(&self) -> usize {
        self.shard_pending.len()
    }

    /// Is domain-shard group `g` fully submitted (its sub-aggregator
    /// could reduce now)?
    pub fn shard_complete(&self, g: usize) -> bool {
        self.shard_pending.get(g) == Some(&0)
    }

    /// Shards whose last in-epoch update has landed this round.
    pub fn shards_complete(&self) -> usize {
        self.shards_complete
    }

    /// `Training → Aggregating`: the round stops executing steps.
    pub fn close(&mut self, timed_out: bool) {
        debug_assert_eq!(self.phase, RoundPhase::Training);
        self.phase = RoundPhase::Aggregating;
        self.timed_out = timed_out;
    }

    /// `Aggregating → RoundEnd`: the (possibly empty) aggregate has
    /// been applied to the global model.
    pub fn round_end(&mut self) {
        debug_assert_eq!(self.phase, RoundPhase::Aggregating);
        self.phase = RoundPhase::RoundEnd;
    }

    /// `RoundEnd → Idle`: per-round bookkeeping is done. Per-slot state
    /// is dropped; the epoch counter survives so late events from this
    /// round stay fenced forever.
    pub fn finish(&mut self) {
        debug_assert_eq!(self.phase, RoundPhase::RoundEnd);
        self.phase = RoundPhase::Idle;
        self.slot_of.clear();
        self.checked_in.clear();
        self.offline_depth.clear();
        self.submitted.clear();
        self.done = 0;
        self.n_required = 0;
        self.shard_group_of_slot.clear();
        self.shard_pending.clear();
        self.shards_complete = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decision(clients: Vec<usize>, n_required: usize) -> SelectionDecision {
        SelectionDecision {
            clients,
            expected_duration: 5,
            n_required,
            max_duration: 10,
            wait: false,
            unconstrained: false,
        }
    }

    #[test]
    fn validate_catches_duplicates_and_unknowns() {
        assert_eq!(
            validate_decision(&decision(vec![1, 2, 1], 2), 5),
            Err(DecisionError::DuplicateClient { client: 1 })
        );
        assert_eq!(
            validate_decision(&decision(vec![0, 7], 2), 5),
            Err(DecisionError::UnknownClient { client: 7, n_clients: 5 })
        );
        assert!(validate_decision(&decision(vec![0, 4, 2], 3), 5).is_ok());
        assert!(validate_decision(&decision(vec![], 0), 5).is_ok());
    }

    #[test]
    fn full_lifecycle_reaches_idle_again() {
        let mut fsm = RoundFsm::new();
        let mut q = EventQueue::new();
        let d = decision(vec![3, 1], 2);
        assert_eq!(fsm.phase(), RoundPhase::Idle);
        fsm.begin_round(&d, 5, 0, 10, &mut q).unwrap();
        assert_eq!(fsm.phase(), RoundPhase::Selecting);
        assert_eq!(fsm.epoch(), 1);
        fsm.start_training();

        // check-ins were queued at t0
        while let Some(ev) = q.pop_due(0) {
            fsm.apply(&ev);
        }
        assert!(fsm.checked_in(0) && fsm.checked_in(1));

        let e = fsm.epoch();
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 3, epoch: e }),
            EventOutcome::Accepted
        );
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 1, epoch: e }),
            EventOutcome::Accepted
        );
        assert!(fsm.quorum());
        fsm.close(false);
        assert_eq!(fsm.phase(), RoundPhase::Aggregating);
        fsm.round_end();
        fsm.finish();
        assert_eq!(fsm.phase(), RoundPhase::Idle);
        // epoch survives the reset
        assert_eq!(fsm.epoch(), 1);
    }

    #[test]
    fn stale_epoch_updates_are_fenced() {
        let mut fsm = RoundFsm::new();
        let mut q = EventQueue::new();
        fsm.begin_round(&decision(vec![0, 1], 2), 3, 0, 10, &mut q).unwrap();
        fsm.start_training();
        // token from a previous round
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 0, epoch: 0 }),
            EventOutcome::StaleUpdate
        );
        assert_eq!(fsm.submissions(), 0);
        // current token after the round closed is equally stale
        fsm.close(true);
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 0, epoch: fsm.epoch() }),
            EventOutcome::StaleUpdate
        );
        assert_eq!(fsm.submissions(), 0);
    }

    #[test]
    fn double_submission_is_rejected() {
        let mut fsm = RoundFsm::new();
        let mut q = EventQueue::new();
        fsm.begin_round(&decision(vec![0], 1), 3, 0, 10, &mut q).unwrap();
        fsm.start_training();
        let e = fsm.epoch();
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 0, epoch: e }),
            EventOutcome::Accepted
        );
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 0, epoch: e }),
            EventOutcome::StaleUpdate
        );
        assert_eq!(fsm.submissions(), 1);
    }

    #[test]
    fn offline_depth_composes_overlapping_windows() {
        let mut fsm = RoundFsm::new();
        let mut q = EventQueue::new();
        fsm.begin_round(&decision(vec![4], 1), 5, 0, 10, &mut q).unwrap();
        fsm.start_training();
        let e = fsm.epoch();
        assert!(fsm.online(0));
        // churn window opens, then a chaos fault overlaps it
        fsm.apply(&ClientEvent::Dropout { client: 4, epoch: e });
        fsm.apply(&ClientEvent::Dropout { client: 4, epoch: e });
        assert!(!fsm.online(0));
        fsm.apply(&ClientEvent::Rejoin { client: 4, epoch: e });
        assert!(!fsm.online(0), "still inside the second window");
        fsm.apply(&ClientEvent::Rejoin { client: 4, epoch: e });
        assert!(fsm.online(0));
        // stale liveness events are ignored
        assert_eq!(
            fsm.apply(&ClientEvent::Dropout { client: 4, epoch: e + 1 }),
            EventOutcome::Ignored
        );
        assert!(fsm.online(0));
    }

    #[test]
    fn timeout_fires_only_for_current_training_round() {
        let mut fsm = RoundFsm::new();
        let mut q = EventQueue::new();
        fsm.begin_round(&decision(vec![0], 1), 3, 0, 10, &mut q).unwrap();
        fsm.start_training();
        assert_eq!(
            fsm.apply(&ClientEvent::Timeout { epoch: 0 }),
            EventOutcome::Ignored
        );
        assert_eq!(
            fsm.apply(&ClientEvent::Timeout { epoch: fsm.epoch() }),
            EventOutcome::TimeoutFired
        );
        fsm.close(true);
        assert!(fsm.timed_out());
        // after close, even the current token is ignored
        assert_eq!(
            fsm.apply(&ClientEvent::Timeout { epoch: fsm.epoch() }),
            EventOutcome::Ignored
        );
    }

    #[test]
    fn shard_completion_tracks_last_in_epoch_update_per_domain() {
        let mut fsm = RoundFsm::new();
        let mut q = EventQueue::new();
        // slots 0..4 = clients [3, 1, 4, 0]; domains 9/2/9/2 → groups
        // in canonical ascending-domain order: g0 = {1, 0}, g1 = {3, 4}
        fsm.begin_round(&decision(vec![3, 1, 4, 0], 4), 5, 0, 10, &mut q).unwrap();
        fsm.assign_domains(&[9, 2, 9, 2]);
        fsm.start_training();
        let e = fsm.epoch();
        assert_eq!(fsm.shard_groups(), 2);
        assert_eq!(fsm.shards_complete(), 0);

        fsm.apply(&ClientEvent::UpdateSubmitted { client: 3, epoch: e });
        assert!(!fsm.shard_complete(1), "domain 9 still owes client 4");
        fsm.apply(&ClientEvent::UpdateSubmitted { client: 4, epoch: e });
        assert!(fsm.shard_complete(1));
        assert!(!fsm.shard_complete(0));
        assert_eq!(fsm.shards_complete(), 1);

        // a stale re-submission must not decrement the shard again
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 4, epoch: e }),
            EventOutcome::StaleUpdate
        );
        assert_eq!(fsm.shards_complete(), 1);

        fsm.apply(&ClientEvent::UpdateSubmitted { client: 1, epoch: e });
        fsm.apply(&ClientEvent::UpdateSubmitted { client: 0, epoch: e });
        assert_eq!(fsm.shards_complete(), 2);
        fsm.close(false);
        fsm.round_end();
        fsm.finish();
        assert_eq!(fsm.shard_groups(), 0, "finish drops shard state");
        assert_eq!(fsm.shards_complete(), 0);
    }

    #[test]
    fn shard_tracking_is_optional() {
        // no assign_domains call: submissions behave exactly as before
        let mut fsm = RoundFsm::new();
        let mut q = EventQueue::new();
        fsm.begin_round(&decision(vec![0, 1], 2), 3, 0, 10, &mut q).unwrap();
        fsm.start_training();
        let e = fsm.epoch();
        assert_eq!(fsm.shard_groups(), 0);
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 0, epoch: e }),
            EventOutcome::Accepted
        );
        assert_eq!(fsm.submissions(), 1);
        assert_eq!(fsm.shards_complete(), 0);
        assert!(!fsm.shard_complete(0));
    }

    #[test]
    fn restored_epoch_keeps_pre_crash_events_fenced() {
        // a machine resumed at epoch 7 mints 8 for its next round, so a
        // stale update carrying a pre-crash token can never count
        let mut fsm = RoundFsm::new();
        fsm.restore_epoch(7);
        let mut q = EventQueue::new();
        fsm.begin_round(&decision(vec![0, 1], 2), 3, 0, 10, &mut q).unwrap();
        assert_eq!(fsm.epoch(), 8);
        fsm.start_training();
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 0, epoch: 7 }),
            EventOutcome::StaleUpdate
        );
        assert_eq!(
            fsm.apply(&ClientEvent::UpdateSubmitted { client: 0, epoch: 8 }),
            EventOutcome::Accepted
        );
    }

    #[test]
    fn begin_round_rejects_malformed_decisions_without_state_change() {
        let mut fsm = RoundFsm::new();
        let mut q = EventQueue::new();
        let err = fsm.begin_round(&decision(vec![2, 2], 2), 5, 0, 10, &mut q);
        assert!(matches!(err, Err(DecisionError::DuplicateClient { client: 2 })));
        assert_eq!(fsm.phase(), RoundPhase::Idle);
        assert_eq!(fsm.epoch(), 0, "no epoch minted for a rejected decision");
        assert!(q.is_empty(), "no events scheduled for a rejected decision");
    }
}
