//! The paper's six baselines (§5.1) plus the unconstrained Upper Bound.
//!
//! * `Random` / `Oort` — select from clients that *currently* have excess
//!   energy and spare capacity; no forecasts.
//! * `Random 1.3n` / `Oort 1.3n` — over-select ⌈1.3·n⌉ clients; the round
//!   ends once n of them responded (the standard straggler mitigation).
//! * `Random fc` / `Oort fc` — select exactly n but use the forecasts to
//!   filter out clients that cannot reach m_min within d_max.
//! * `Upper bound` — random selection, no energy/capacity constraints at
//!   runtime (also uses grid energy; reported separately in Appendix A).

use super::{SelectionContext, SelectionDecision, Strategy};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ranking {
    Random,
    /// rank by σ_c (statistical utility), with ε-greedy exploration
    Oort,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Filter {
    /// clients that have excess energy + spare capacity right now
    AvailableNow,
    /// additionally: forecasts say m_min is reachable within d_max ("fc")
    ForecastReachable,
}

pub struct Baseline {
    pub ranking: Ranking,
    pub filter: Filter,
    /// over-selection factor (1.0 or 1.3)
    pub over_select: f64,
    /// Oort's exploration fraction
    pub epsilon: f64,
    name: &'static str,
}

impl Baseline {
    pub fn random() -> Self {
        Baseline {
            ranking: Ranking::Random,
            filter: Filter::AvailableNow,
            over_select: 1.0,
            epsilon: 0.0,
            name: "Random",
        }
    }

    pub fn random_over() -> Self {
        Baseline { over_select: 1.3, name: "Random 1.3n", ..Self::random() }
    }

    pub fn random_fc() -> Self {
        Baseline {
            filter: Filter::ForecastReachable,
            name: "Random fc",
            ..Self::random()
        }
    }

    pub fn oort() -> Self {
        Baseline {
            ranking: Ranking::Oort,
            filter: Filter::AvailableNow,
            over_select: 1.0,
            epsilon: 0.1,
            name: "Oort",
        }
    }

    pub fn oort_over() -> Self {
        Baseline { over_select: 1.3, name: "Oort 1.3n", ..Self::oort() }
    }

    pub fn oort_fc() -> Self {
        Baseline {
            filter: Filter::ForecastReachable,
            name: "Oort fc",
            ..Self::oort()
        }
    }

    fn candidates(&self, ctx: &SelectionContext) -> Vec<usize> {
        let avail = ctx.available_now();
        match self.filter {
            Filter::AvailableNow => avail,
            Filter::ForecastReachable => avail
                .into_iter()
                .filter(|&i| ctx.reachable_min(i, ctx.d_max))
                .collect(),
        }
    }
}

impl Strategy for Baseline {
    fn name(&self) -> &'static str {
        self.name
    }

    fn needs_forecasts(&self) -> bool {
        self.filter == Filter::ForecastReachable
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> SelectionDecision {
        let mut cands = self.candidates(ctx);
        let want = ((ctx.n as f64 * self.over_select).ceil() as usize).max(ctx.n);
        if cands.len() < ctx.n {
            return SelectionDecision::wait();
        }
        let take = want.min(cands.len());
        let chosen: Vec<usize> = match self.ranking {
            Ranking::Random => {
                let idx = rng.sample_indices(cands.len(), take);
                idx.into_iter().map(|k| cands[k]).collect()
            }
            Ranking::Oort => {
                // ε-greedy: (1-ε)·take by utility, rest random
                cands.sort_by(|&a, &b| {
                    ctx.states[b]
                        .sigma
                        .partial_cmp(&ctx.states[a].sigma)
                        .unwrap()
                });
                let exploit =
                    (((1.0 - self.epsilon) * take as f64).round() as usize).min(take);
                let mut chosen: Vec<usize> = cands[..exploit].to_vec();
                let rest: Vec<usize> = cands[exploit..].to_vec();
                let explore = take - exploit;
                if explore > 0 && !rest.is_empty() {
                    let idx =
                        rng.sample_indices(rest.len(), explore.min(rest.len()));
                    chosen.extend(idx.into_iter().map(|k| rest[k]));
                }
                chosen
            }
        };
        SelectionDecision {
            n_required: ctx.n.min(chosen.len()),
            clients: chosen,
            expected_duration: ctx.d_max,
            max_duration: ctx.d_max,
            wait: false,
            unconstrained: false,
        }
    }
}

/// Random selection with NO energy/capacity constraints (paper's Upper
/// bound; uses grid energy, so it is excluded from the zero-carbon claim).
pub struct UpperBound;

impl Strategy for UpperBound {
    fn name(&self) -> &'static str {
        "Upper bound"
    }

    fn needs_forecasts(&self) -> bool {
        false
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> SelectionDecision {
        let idx = rng.sample_indices(ctx.clients.len(), ctx.n.min(ctx.clients.len()));
        SelectionDecision {
            n_required: idx.len(),
            clients: idx,
            expected_duration: ctx.d_max,
            max_duration: ctx.d_max,
            wait: false,
            unconstrained: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
    use crate::energy::PowerDomain;
    use crate::selection::ClientRoundState;
    use crate::trace::forecast::SeriesForecaster;

    struct Fixture {
        clients: Vec<ClientInfo>,
        states: Vec<ClientRoundState>,
        domains: Vec<PowerDomain>,
        energy_fc: Vec<Vec<f64>>,
        spare_fc: Vec<Vec<f64>>,
        spare_now: Vec<f64>,
    }

    impl Fixture {
        fn bufs(&self) -> crate::selection::ring::FcBuffers {
            crate::selection::ring::FcBuffers::from_rows(
                &self.energy_fc,
                &self.spare_fc,
                60,
            )
        }
    }

    fn fixture(n_clients: usize, n_domains: usize, power_w: f64) -> Fixture {
        let clients: Vec<ClientInfo> = (0..n_clients)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::Mid,
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                ClientInfo::new(i, i % n_domains, p, (0..50).collect(), 10)
            })
            .collect();
        let domains: Vec<PowerDomain> = (0..n_domains)
            .map(|i| {
                let series = vec![power_w; 120];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let energy_fc = domains
            .iter()
            .map(|d| d.forecast_window_wh(0, 60))
            .collect();
        let spare_fc = clients
            .iter()
            .map(|c| vec![c.capacity(); 60])
            .collect();
        let spare_now = clients.iter().map(|c| c.capacity()).collect();
        Fixture {
            states: vec![ClientRoundState::default(); n_clients],
            clients,
            domains,
            energy_fc,
            spare_fc,
            spare_now,
        }
    }

    fn ctx<'a>(
        f: &'a Fixture,
        bufs: &'a crate::selection::ring::FcBuffers,
        n: usize,
    ) -> SelectionContext<'a> {
        SelectionContext {
            now: 0,
            n,
            d_max: 60,
            clients: &f.clients,
            states: &f.states,
            domains: &f.domains,
            fc: bufs.view(),
            incr: None,
            spare_now: &f.spare_now,
        }
    }

    #[test]
    fn random_selects_n_distinct_available() {
        let f = fixture(20, 4, 500.0);
        let mut s = Baseline::random();
        let mut rng = Rng::new(0);
        let b = f.bufs();
        let d = s.select(&ctx(&f, &b, 5), &mut rng);
        assert_eq!(d.clients.len(), 5);
        assert_eq!(d.n_required, 5);
        let mut u = d.clients.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 5);
    }

    #[test]
    fn over_selection_takes_30_percent_more() {
        let f = fixture(30, 5, 500.0);
        let mut s = Baseline::oort_over();
        let mut rng = Rng::new(1);
        let b = f.bufs();
        let d = s.select(&ctx(&f, &b, 10), &mut rng);
        assert_eq!(d.clients.len(), 13); // ceil(1.3 * 10)
        assert_eq!(d.n_required, 10);
    }

    #[test]
    fn waits_when_dark() {
        let f = fixture(10, 2, 0.0);
        let b = f.bufs();
        for strat in [Baseline::random(), Baseline::oort(), Baseline::random_fc()] {
            let mut s = strat;
            let mut rng = Rng::new(2);
            assert!(s.select(&ctx(&f, &b, 3), &mut rng).wait, "{}", s.name());
        }
    }

    #[test]
    fn oort_prefers_high_sigma() {
        let mut f = fixture(20, 4, 500.0);
        for (i, st) in f.states.iter_mut().enumerate() {
            st.sigma = if i < 5 { 100.0 } else { 1.0 };
        }
        let mut s = Baseline::oort();
        let b = f.bufs();
        let mut hits = 0;
        for seed in 0..50 {
            let mut rng = Rng::new(seed);
            let d = s.select(&ctx(&f, &b, 5), &mut rng);
            hits += d.clients.iter().filter(|&&c| c < 5).count();
        }
        // ~90% exploitation should put most picks on the high-σ clients
        assert!(hits > 150, "hits={hits}/250");
    }

    #[test]
    fn fc_filter_drops_unreachable_clients() {
        let mut f = fixture(10, 2, 500.0);
        // client 0 has zero spare in the forecast -> unreachable
        f.spare_fc[0] = vec![0.0; 60];
        let mut s = Baseline::random_fc();
        let b = f.bufs();
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let d = s.select(&ctx(&f, &b, 4), &mut rng);
            assert!(!d.clients.contains(&0));
        }
    }

    #[test]
    fn upper_bound_ignores_constraints() {
        let f = fixture(10, 2, 0.0); // no energy at all
        let mut s = UpperBound;
        let b = f.bufs();
        let mut rng = Rng::new(3);
        let d = s.select(&ctx(&f, &b, 4), &mut rng);
        assert!(!d.wait);
        assert_eq!(d.clients.len(), 4);
        assert!(d.unconstrained);
    }
}
