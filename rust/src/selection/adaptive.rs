//! Churn-aware reactive over-selection (satellite of the robustness PR).
//!
//! The paper's over-selection baselines (`Random 1.3n`, `Oort 1.3n`) pad
//! the cohort by a FIXED factor, paid on every round whether clients
//! actually drop or not. This wrapper instead tracks the *observed*
//! per-round dropout rate `p̂` (EWMA over `1 − participants/selected`)
//! and asks the inner strategy for
//!
//! ```text
//! n' = min( ceil(n · 1/(1 − min(p̂, 0.9))), MAX_FACTOR·n, |clients| )
//! ```
//!
//! clients — no churn observed ⇒ no padding ⇒ bit-identical to the
//! inner strategy; heavy churn ⇒ up to `MAX_FACTOR`× over-selection.
//! It is the first *reactive* strategy in the repo and is evaluated on
//! the campaign's churn/chaos axes as `FedZero ca` / `SemiSync ca`.
//!
//! Quorum semantics differ by inner strategy:
//!
//! * wrapping an as-soon-as-quorum policy (FedZero), `override_quorum`
//!   pins `n_required` back to the original `n` — the padding exists
//!   purely to absorb dropouts, not to demand more completions;
//! * wrapping SemiSync (`override_quorum = false`), the inner wrapper
//!   already sets `n_required = |clients|` with a fixed deadline, and
//!   the round closes on the deadline's `Timeout` event regardless.
//!
//! If the inner strategy cannot fill the boosted cohort (`wait`), we
//! fall back to the un-boosted request rather than stalling the round.

use super::{ClientRoundState, SelectionContext, SelectionDecision, Strategy};
use crate::util::json::{num, obj, Json};
use crate::util::rng::Rng;

/// EWMA weight for the newest round's observed dropout rate.
const EMA_ALPHA: f64 = 0.3;
/// Over-selection never exceeds this multiple of the requested n.
const MAX_FACTOR: f64 = 2.0;

pub struct ChurnAware<S: Strategy> {
    pub inner: S,
    name: &'static str,
    /// EWMA of the observed per-round dropout rate, in [0, 1)
    p_hat: f64,
    /// pin `n_required` back to the un-boosted n (see module docs)
    override_quorum: bool,
    /// cohort size of the last non-wait decision (EWMA denominator)
    last_selected: usize,
}

impl<S: Strategy> ChurnAware<S> {
    pub fn new(inner: S, name: &'static str, override_quorum: bool) -> Self {
        ChurnAware { inner, name, p_hat: 0.0, override_quorum, last_selected: 0 }
    }

    /// current dropout-rate estimate (exposed for tests/reporting)
    pub fn p_hat(&self) -> f64 {
        self.p_hat
    }

    fn boosted_n(&self, ctx: &SelectionContext) -> usize {
        let factor = (1.0 / (1.0 - self.p_hat.min(0.9))).min(MAX_FACTOR);
        let boosted = ((ctx.n as f64) * factor).ceil() as usize;
        boosted.max(ctx.n).min(ctx.clients.len())
    }
}

impl<S: Strategy> Strategy for ChurnAware<S> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn needs_forecasts(&self) -> bool {
        self.inner.needs_forecasts()
    }

    fn needs_spare_now(&self) -> bool {
        self.inner.needs_spare_now()
    }

    fn uses_selection_state(&self) -> bool {
        self.inner.uses_selection_state()
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> SelectionDecision {
        let boosted = self.boosted_n(ctx);
        let mut d = if boosted > ctx.n {
            let boosted_ctx = SelectionContext {
                now: ctx.now,
                n: boosted,
                d_max: ctx.d_max,
                clients: ctx.clients,
                states: ctx.states,
                domains: ctx.domains,
                fc: ctx.fc,
                incr: ctx.incr,
                spare_now: ctx.spare_now,
            };
            let d = self.inner.select(&boosted_ctx, rng);
            if d.wait {
                // the environment can't feed the padded cohort right now —
                // degrade to the plain request instead of stalling
                self.inner.select(ctx, rng)
            } else {
                d
            }
        } else {
            self.inner.select(ctx, rng)
        };
        if d.wait {
            return d;
        }
        if self.override_quorum {
            d.n_required = ctx.n.min(d.clients.len());
        }
        self.last_selected = d.clients.len();
        d
    }

    fn on_round_end(
        &mut self,
        participants: &[usize],
        states: &mut [ClientRoundState],
        rng: &mut Rng,
    ) {
        if self.last_selected > 0 {
            let observed =
                1.0 - (participants.len() as f64 / self.last_selected as f64);
            self.p_hat = (1.0 - EMA_ALPHA) * self.p_hat + EMA_ALPHA * observed;
        }
        self.inner.on_round_end(participants, states, rng);
    }

    fn snapshot_state(&self) -> Option<Json> {
        // the EWMA and its denominator are the only cross-round state;
        // the inner strategy may contribute its own (SemiSync delegates
        // through, so nesting composes)
        let mut pairs = vec![
            ("p_hat", num(self.p_hat)),
            ("last_selected", num(self.last_selected as f64)),
        ];
        let inner = self.inner.snapshot_state();
        if let Some(st) = inner {
            pairs.push(("inner", st));
        }
        Some(obj(pairs))
    }

    fn restore_state(&mut self, state: &Json) -> anyhow::Result<()> {
        self.p_hat = state
            .get("p_hat")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("ChurnAware checkpoint missing p_hat"))?;
        self.last_selected = state
            .get("last_selected")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| {
                anyhow::anyhow!("ChurnAware checkpoint missing last_selected")
            })?;
        if let Some(inner) = state.get("inner") {
            self.inner.restore_state(inner)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
    use crate::energy::PowerDomain;
    use crate::selection::baselines::Baseline;
    use crate::selection::fedzero::{FedZero, SolverKind};
    use crate::trace::forecast::SeriesForecaster;

    fn fixture() -> (
        Vec<ClientInfo>,
        Vec<ClientRoundState>,
        Vec<PowerDomain>,
        Vec<Vec<f64>>,
        Vec<Vec<f64>>,
        Vec<f64>,
    ) {
        let clients: Vec<ClientInfo> = (0..8)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::Mid,
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                ClientInfo::new(i, i % 2, p, (0..50).collect(), 10)
            })
            .collect();
        let domains: Vec<PowerDomain> = (0..2)
            .map(|i| {
                let series = vec![700.0; 120];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let states = vec![ClientRoundState::default(); 8];
        let energy_fc =
            domains.iter().map(|d| d.forecast_window_wh(0, 60)).collect();
        let spare_fc =
            clients.iter().map(|c| vec![c.capacity(); 60]).collect();
        let spare_now = clients.iter().map(|c| c.capacity()).collect();
        (clients, states, domains, energy_fc, spare_fc, spare_now)
    }

    fn ctx<'a>(
        n: usize,
        clients: &'a [ClientInfo],
        states: &'a [ClientRoundState],
        domains: &'a [PowerDomain],
        fcb: &'a crate::selection::ring::FcBuffers,
        snow: &'a [f64],
    ) -> SelectionContext<'a> {
        SelectionContext {
            now: 0,
            n,
            d_max: 60,
            clients,
            states,
            domains,
            fc: fcb.view(),
            incr: None,
            spare_now: snow,
        }
    }

    #[test]
    fn no_observed_churn_means_no_boost() {
        let (clients, states, domains, efc, sfc, snow) = fixture();
        let fcb = crate::selection::ring::FcBuffers::from_rows(&efc, &sfc, 60);
        let c = ctx(3, &clients, &states, &domains, &fcb, &snow);
        let mut plain = Baseline::random();
        let mut wrapped = ChurnAware::new(Baseline::random(), "ca", true);
        // same rng stream, p_hat = 0 → bit-identical decisions
        let d0 = plain.select(&c, &mut Rng::new(7));
        let d1 = wrapped.select(&c, &mut Rng::new(7));
        assert_eq!(d0, d1);
        assert_eq!(wrapped.p_hat(), 0.0);
    }

    #[test]
    fn observed_dropouts_grow_the_cohort_with_pinned_quorum() {
        let (clients, states, domains, efc, sfc, snow) = fixture();
        let fcb = crate::selection::ring::FcBuffers::from_rows(&efc, &sfc, 60);
        let c = ctx(3, &clients, &states, &domains, &fcb, &snow);
        let mut rng = Rng::new(7);
        let mut s = ChurnAware::new(Baseline::random(), "ca", true);
        // several rounds where 2 of 3 selected clients drop
        let mut states_mut = states.clone();
        for _ in 0..8 {
            let d = s.select(&c, &mut rng);
            assert!(!d.wait);
            s.on_round_end(&d.clients[..1], &mut states_mut, &mut rng);
        }
        assert!(s.p_hat() > 0.3, "EWMA should have converged upward");
        let d = s.select(&c, &mut rng);
        assert!(d.clients.len() > 3, "cohort should be over-selected");
        assert_eq!(d.n_required, 3, "quorum stays at the requested n");
    }

    #[test]
    fn boost_is_capped_by_factor_and_population() {
        let (clients, states, domains, efc, sfc, snow) = fixture();
        let fcb = crate::selection::ring::FcBuffers::from_rows(&efc, &sfc, 60);
        let c = ctx(5, &clients, &states, &domains, &fcb, &snow);
        let mut s = ChurnAware::new(Baseline::random(), "ca", true);
        s.p_hat = 0.99; // extreme churn: rate clamps to 0.9, factor to 2.0
        assert_eq!(s.boosted_n(&c), 8); // ceil(5·2) = 10 → capped to 8 clients
        s.p_hat = 0.5; // factor 2.0 → ceil(5·2)=10 → capped to 8 clients
        assert_eq!(s.boosted_n(&c), 8);
        s.p_hat = 0.25; // factor 4/3 → ceil(5·4/3) = 7
        assert_eq!(s.boosted_n(&c), 7);
    }

    #[test]
    fn composes_with_fedzero_and_recovers_downward() {
        let (clients, states, domains, efc, sfc, snow) = fixture();
        let fcb = crate::selection::ring::FcBuffers::from_rows(&efc, &sfc, 60);
        let c = ctx(2, &clients, &states, &domains, &fcb, &snow);
        let mut rng = Rng::new(1);
        let mut s =
            ChurnAware::new(FedZero::new(SolverKind::Greedy), "FedZero ca", true);
        s.p_hat = 0.5;
        let d = s.select(&c, &mut rng);
        assert!(!d.wait);
        assert!(d.clients.len() > 2);
        assert_eq!(d.n_required, 2);
        // churn subsides: full participation decays p_hat toward 0
        let mut states_mut = states.clone();
        let before = s.p_hat();
        s.on_round_end(&d.clients.clone(), &mut states_mut, &mut rng);
        assert!(s.p_hat() < before);
    }

    #[test]
    fn snapshot_state_roundtrips_the_estimator() {
        let mut s = ChurnAware::new(Baseline::random(), "ca", true);
        s.p_hat = 0.375;
        s.last_selected = 6;
        let snap = s.snapshot_state().expect("ChurnAware is stateful");
        let mut restored = ChurnAware::new(Baseline::random(), "ca", true);
        restored.restore_state(&snap).unwrap();
        assert_eq!(restored.p_hat.to_bits(), s.p_hat.to_bits());
        assert_eq!(restored.last_selected, 6);
        // stateless strategies advertise no checkpoint state
        assert!(Baseline::random().snapshot_state().is_none());
    }

    #[test]
    fn wait_passes_through_untouched() {
        let (clients, states, _domains, _efc, sfc, snow) = fixture();
        let domains: Vec<PowerDomain> = (0..2)
            .map(|i| {
                let series = vec![0.0; 120];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let efc: Vec<Vec<f64>> =
            domains.iter().map(|d| d.forecast_window_wh(0, 60)).collect();
        let fcb = crate::selection::ring::FcBuffers::from_rows(&efc, &sfc, 60);
        let c = ctx(2, &clients, &states, &domains, &fcb, &snow);
        let mut rng = Rng::new(2);
        let mut s =
            ChurnAware::new(FedZero::new(SolverKind::Greedy), "FedZero ca", true);
        s.p_hat = 0.5;
        assert!(s.select(&c, &mut rng).wait);
    }
}
