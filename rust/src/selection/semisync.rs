//! Semi-synchronous round extension (paper §7 future work: integrating
//! FedZero with semi-synchronous strategies such as REFL [1]).
//!
//! Instead of ending the round as soon as the selected clients complete
//! their minimum participation, a semi-synchronous server aggregates at a
//! FIXED deadline with whichever clients finished by then. This trades
//! straggler tolerance for potentially discarded work. Implemented as a
//! wrapper so it composes with any underlying selection policy (FedZero,
//! Random, Oort).
//!
//! The deadline is enforced through `max_duration`: under the
//! event-driven engine ([`crate::coordinator::fsm`]) it becomes the
//! round's `Timeout` event, so a semi-sync round closes exactly like any
//! timed-out round — gracefully, with whatever participants finished —
//! and late submissions are epoch-fenced and metered rather than
//! silently aggregated.

use super::{ClientRoundState, SelectionContext, SelectionDecision, Strategy};
use crate::util::rng::Rng;

pub struct SemiSync<S: Strategy> {
    pub inner: S,
    /// fixed aggregation deadline in timesteps
    pub deadline: usize,
}

impl<S: Strategy> SemiSync<S> {
    pub fn new(inner: S, deadline: usize) -> Self {
        assert!(deadline >= 1);
        SemiSync { inner, deadline }
    }
}

impl<S: Strategy> Strategy for SemiSync<S> {
    fn name(&self) -> &'static str {
        "SemiSync"
    }

    fn needs_forecasts(&self) -> bool {
        self.inner.needs_forecasts()
    }

    fn needs_spare_now(&self) -> bool {
        self.inner.needs_spare_now()
    }

    fn uses_selection_state(&self) -> bool {
        self.inner.uses_selection_state()
    }

    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> SelectionDecision {
        let mut d = self.inner.select(ctx, rng);
        if d.wait {
            return d;
        }
        // rounds last exactly `deadline` steps (or until everyone is done)
        d.max_duration = self.deadline.min(ctx.d_max);
        d.n_required = d.clients.len();
        d.expected_duration = d.max_duration;
        d
    }

    fn on_round_end(
        &mut self,
        participants: &[usize],
        states: &mut [ClientRoundState],
        rng: &mut Rng,
    ) {
        self.inner.on_round_end(participants, states, rng);
    }

    // the wrapper itself is config-only (deadline); checkpoint state, if
    // any, belongs to the inner policy — delegate both hooks so nesting
    // (e.g. ChurnAware<SemiSync<FedZero>>) composes
    fn snapshot_state(&self) -> Option<crate::util::json::Json> {
        self.inner.snapshot_state()
    }

    fn restore_state(&mut self, state: &crate::util::json::Json) -> anyhow::Result<()> {
        self.inner.restore_state(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::baselines::Baseline;
    use crate::selection::fedzero::{FedZero, SolverKind};
    use crate::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
    use crate::energy::PowerDomain;
    use crate::trace::forecast::SeriesForecaster;

    fn fixture() -> (
        Vec<ClientInfo>,
        Vec<ClientRoundState>,
        Vec<PowerDomain>,
        Vec<Vec<f64>>,
        Vec<Vec<f64>>,
        Vec<f64>,
    ) {
        let clients: Vec<ClientInfo> = (0..8)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::Mid,
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                ClientInfo::new(i, i % 2, p, (0..50).collect(), 10)
            })
            .collect();
        let domains: Vec<PowerDomain> = (0..2)
            .map(|i| {
                let series = vec![700.0; 120];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let states = vec![ClientRoundState::default(); 8];
        let energy_fc =
            domains.iter().map(|d| d.forecast_window_wh(0, 60)).collect();
        let spare_fc =
            clients.iter().map(|c| vec![c.capacity(); 60]).collect();
        let spare_now = clients.iter().map(|c| c.capacity()).collect();
        (clients, states, domains, energy_fc, spare_fc, spare_now)
    }

    #[test]
    fn deadline_caps_round_duration() {
        let (clients, states, domains, efc, sfc, snow) = fixture();
        let fcb = crate::selection::ring::FcBuffers::from_rows(&efc, &sfc, 60);
        let ctx = SelectionContext {
            now: 0,
            n: 3,
            d_max: 60,
            clients: &clients,
            states: &states,
            domains: &domains,
            fc: fcb.view(),
            incr: None,
            spare_now: &snow,
        };
        let mut rng = Rng::new(0);
        let mut s = SemiSync::new(Baseline::random(), 15);
        let d = s.select(&ctx, &mut rng);
        assert!(!d.wait);
        assert_eq!(d.max_duration, 15);
        assert_eq!(d.n_required, d.clients.len());
    }

    #[test]
    fn composes_with_fedzero() {
        let (clients, states, domains, efc, sfc, snow) = fixture();
        let fcb = crate::selection::ring::FcBuffers::from_rows(&efc, &sfc, 60);
        let ctx = SelectionContext {
            now: 0,
            n: 2,
            d_max: 60,
            clients: &clients,
            states: &states,
            domains: &domains,
            fc: fcb.view(),
            incr: None,
            spare_now: &snow,
        };
        let mut rng = Rng::new(1);
        let mut s = SemiSync::new(FedZero::new(SolverKind::Greedy), 10);
        let d = s.select(&ctx, &mut rng);
        assert!(!d.wait);
        assert_eq!(d.clients.len(), 2);
        assert!(d.max_duration <= 10);
    }

    #[test]
    fn wait_passes_through() {
        let (clients, states, _domains, _efc, sfc, snow) = fixture();
        // dark domains
        let domains: Vec<PowerDomain> = (0..2)
            .map(|i| {
                let series = vec![0.0; 120];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let efc: Vec<Vec<f64>> =
            domains.iter().map(|d| d.forecast_window_wh(0, 60)).collect();
        let fcb = crate::selection::ring::FcBuffers::from_rows(&efc, &sfc, 60);
        let ctx = SelectionContext {
            now: 0,
            n: 2,
            d_max: 60,
            clients: &clients,
            states: &states,
            domains: &domains,
            fc: fcb.view(),
            incr: None,
            spare_now: &snow,
        };
        let mut rng = Rng::new(2);
        let mut s = SemiSync::new(FedZero::new(SolverKind::Greedy), 10);
        assert!(s.select(&ctx, &mut rng).wait);
    }
}
