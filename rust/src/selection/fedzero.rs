//! FedZero's client selection — Algorithm 1 of the paper.
//!
//! Binary search over the round duration d ∈ [1, d_max] (feasibility is
//! monotone in d: a longer window only adds energy and spare capacity),
//! with per-d pre-filters:
//!   * power domains without any forecast excess energy in the window,
//!   * clients on the blocklist (σ_c = 0),
//!   * clients that cannot reach m_min within d even with the whole
//!     domain budget to themselves (line 11).
//! The surviving instance goes to the selection solver: the scalable
//! greedy+local-search by default, exact branch-and-bound on request
//! (`SolverKind::Exact`), both from [`crate::solver::mip`].
//!
//! §Perf: one [`SelArena`] is built per `select()` call; every probe of
//! the binary search borrows slice views into it through a reused
//! [`ProbeScratch`] (see `selection::arena` and the §Perf notes in
//! `solver::mip`). The pre-filters — formerly duplicated between
//! `build_instance` and `eligible_ids`, which could silently diverge —
//! now live once in `SelArena::fill_probe`, which yields the solver
//! instance together with its parallel id map.

use super::arena::{ProbeScratch, SelArena};
use super::fairness::Blocklist;
use super::{ClientRoundState, SelectionContext, SelectionDecision, Strategy};
use crate::solver::alloc::AllocWorkspace;
use crate::solver::mip::{self, InstanceView};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// greedy + swap local search (scales to 100k clients; default)
    Greedy,
    /// exact branch-and-bound with a node budget (falls back to greedy
    /// incumbent when exhausted)
    Exact,
}

pub struct FedZero {
    pub solver: SolverKind,
    pub blocklist: Blocklist,
    /// swap passes for the greedy solver
    pub swap_passes: usize,
    /// node budget for the exact solver
    pub node_budget: usize,
    /// statistics: (d searched, eligible clients) of the last selection
    pub last_search: Option<(usize, usize)>,
}

impl FedZero {
    pub fn new(solver: SolverKind) -> Self {
        FedZero {
            solver,
            blocklist: Blocklist::new(1.0),
            swap_passes: 1,
            node_budget: 200_000,
            last_search: None,
        }
    }

    fn solve_view(&self, inst: InstanceView<'_>, ws: &mut AllocWorkspace) -> mip::SelSolution {
        match self.solver {
            SolverKind::Greedy => mip::greedy_view(inst, self.swap_passes, ws),
            SolverKind::Exact => mip::branch_and_bound_view(inst, self.node_budget, ws),
        }
    }

    /// Algorithm 1: smallest d with a full-size solution, via binary
    /// search over probe views into `arena`. All probes share one scratch
    /// and one solver workspace.
    fn search(&mut self, arena: &SelArena<'_>, n: usize, d_max: usize) -> Option<(Vec<usize>, usize)> {
        let mut scratch = ProbeScratch::new();
        let mut ws = AllocWorkspace::default();
        let mut lo = 1usize;
        let mut hi = d_max;
        let mut best: Option<(Vec<usize>, usize)> = None;
        while lo <= hi {
            let d = lo + (hi - lo) / 2;
            let attempt = if arena.fill_probe(&mut scratch, d) {
                let sol = self.solve_view(scratch.instance(), &mut ws);
                if sol.chosen.len() == n {
                    Some(sol.chosen.iter().map(|&k| scratch.ids[k]).collect::<Vec<_>>())
                } else {
                    None
                }
            } else {
                None
            };
            match attempt {
                Some(ids) => {
                    best = Some((ids, d));
                    if d == 1 {
                        break;
                    }
                    hi = d - 1;
                }
                None => {
                    lo = d + 1;
                }
            }
        }
        best
    }
}

impl Strategy for FedZero {
    fn name(&self) -> &'static str {
        match self.solver {
            SolverKind::Greedy => "FedZero",
            SolverKind::Exact => "FedZero(exact)",
        }
    }

    fn needs_spare_now(&self) -> bool {
        false // every FedZero filter is forecast-driven
    }

    fn uses_selection_state(&self) -> bool {
        true // SelArena borrows ctx.incr when the engine maintains it
    }

    fn select(&mut self, ctx: &SelectionContext, _rng: &mut Rng) -> SelectionDecision {
        // §Perf: cheap necessary condition before any arena work — if
        // fewer than n clients are even standalone-eligible at d_max, no d
        // can work; skip both the arena build and the O(log d · greedy)
        // search during dark periods. With the persistent incremental
        // selection state (selection::incr) attached this gate is a pure
        // O(D) counter sum — a fully dark idle poll touches no client and
        // no forecast row at all; the fresh fallback is allocation-free
        // and short-circuits dead domains via O(1) liveness counters.
        if SelArena::quick_eligible_count(ctx) < ctx.n {
            return SelectionDecision::wait();
        }
        // the arena borrows the context's forecast window (no row copies)
        // and, when attached, the persistent reach structures (no
        // O(C·d_max) recompute); every probe below borrows slice views
        let arena = SelArena::build(ctx);
        match self.search(&arena, ctx.n, ctx.d_max) {
            Some((clients, d)) => {
                self.last_search = Some((d, clients.len()));
                let n_required = clients.len();
                SelectionDecision {
                    clients,
                    expected_duration: d,
                    n_required,
                    max_duration: ctx.d_max,
                    wait: false,
                    unconstrained: false,
                }
            }
            None => SelectionDecision::wait(),
        }
    }

    fn on_round_end(
        &mut self,
        participants: &[usize],
        states: &mut [ClientRoundState],
        rng: &mut Rng,
    ) {
        self.blocklist.block(participants, states);
        self.blocklist.begin_round(states, rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
    use crate::energy::PowerDomain;
    use crate::trace::forecast::SeriesForecaster;

    fn mk_clients(n: usize, domains: usize, samples: usize) -> Vec<ClientInfo> {
        (0..n)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::ALL[i % 3],
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                ClientInfo::new(i, i % domains, p, (0..samples).collect(), 10)
            })
            .collect()
    }

    fn mk_domains(n: usize, power_w: f64, steps: usize) -> Vec<PowerDomain> {
        (0..n)
            .map(|i| {
                let series = vec![power_w; steps];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect()
    }

    fn mk_ctx<'a>(
        clients: &'a [ClientInfo],
        states: &'a [ClientRoundState],
        domains: &'a [PowerDomain],
        fc: crate::selection::ring::FcView<'a>,
        spare_now: &'a [f64],
        n: usize,
        d_max: usize,
    ) -> SelectionContext<'a> {
        SelectionContext {
            now: 0,
            n,
            d_max,
            clients,
            states,
            domains,
            fc,
            incr: None,
            spare_now,
        }
    }

    fn full_forecasts(
        clients: &[ClientInfo],
        domains: &[PowerDomain],
        d_max: usize,
    ) -> (crate::selection::ring::FcBuffers, Vec<f64>) {
        let energy_fc: Vec<Vec<f64>> = domains
            .iter()
            .map(|d| d.forecast_window_wh(0, d_max))
            .collect();
        let spare_fc: Vec<Vec<f64>> = clients
            .iter()
            .map(|c| vec![c.capacity(); d_max])
            .collect();
        let spare_now: Vec<f64> = clients.iter().map(|c| c.capacity()).collect();
        (
            crate::selection::ring::FcBuffers::from_rows(&energy_fc, &spare_fc, d_max),
            spare_now,
        )
    }

    #[test]
    fn selects_n_and_short_duration_when_plentiful() {
        let clients = mk_clients(12, 3, 50);
        let states = vec![ClientRoundState::default(); 12];
        let domains = mk_domains(3, 800.0, 120);
        let (fcb, snow) = full_forecasts(&clients, &domains, 60);
        let ctx = mk_ctx(&clients, &states, &domains, fcb.view(), &snow, 4, 60);
        let mut fz = FedZero::new(SolverKind::Greedy);
        let mut rng = Rng::new(0);
        let d = fz.select(&ctx, &mut rng);
        assert!(!d.wait);
        assert_eq!(d.clients.len(), 4);
        // plenty of energy: each client needs m_min=5 batches at ~38
        // batches/step capacity -> d=1 must suffice
        assert_eq!(d.expected_duration, 1, "expected shortest duration");
    }

    #[test]
    fn waits_when_no_energy() {
        let clients = mk_clients(6, 2, 50);
        let states = vec![ClientRoundState::default(); 6];
        let domains = mk_domains(2, 0.0, 120);
        let (fcb, snow) = full_forecasts(&clients, &domains, 60);
        let ctx = mk_ctx(&clients, &states, &domains, fcb.view(), &snow, 2, 60);
        let mut fz = FedZero::new(SolverKind::Greedy);
        let mut rng = Rng::new(0);
        assert!(fz.select(&ctx, &mut rng).wait);
    }

    #[test]
    fn blocked_clients_are_never_selected() {
        let clients = mk_clients(8, 2, 50);
        let mut states = vec![ClientRoundState::default(); 8];
        for i in 0..4 {
            states[i].blocked = true;
            states[i].sigma = 0.0;
        }
        let domains = mk_domains(2, 800.0, 120);
        let (fcb, snow) = full_forecasts(&clients, &domains, 60);
        let ctx = mk_ctx(&clients, &states, &domains, fcb.view(), &snow, 3, 60);
        let mut fz = FedZero::new(SolverKind::Greedy);
        let mut rng = Rng::new(0);
        let d = fz.select(&ctx, &mut rng);
        assert!(!d.wait);
        assert!(d.clients.iter().all(|&c| c >= 4), "{:?}", d.clients);
    }

    #[test]
    fn duration_grows_when_energy_is_scarce() {
        // energy only supports a fraction of a batch per step -> need
        // several steps to reach m_min
        let clients = mk_clients(4, 1, 50); // m_min = 5 batches
        let states = vec![ClientRoundState::default(); 4];
        // small device: δ ≈ 70*(10/110)/60 ≈ 0.106 Wh/batch; give 13 Wh/h
        let domains = mk_domains(1, 13.0, 240);
        let (fcb, snow) = full_forecasts(&clients, &domains, 120);
        let ctx = mk_ctx(&clients, &states, &domains, fcb.view(), &snow, 2, 120);
        let mut fz = FedZero::new(SolverKind::Greedy);
        let mut rng = Rng::new(0);
        let d = fz.select(&ctx, &mut rng);
        assert!(!d.wait);
        assert!(d.expected_duration > 1, "d={}", d.expected_duration);
        assert_eq!(d.clients.len(), 2);
    }

    #[test]
    fn round_end_blocks_participants() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let mut states = vec![ClientRoundState::default(); 5];
        states[1].participation = 1;
        states[3].participation = 1;
        let mut rng = Rng::new(0);
        fz.on_round_end(&[1, 3], &mut states, &mut rng);
        // 1 and 3 were just blocked; they may be instantly released (p <=
        // omega), but sigma handling happens via the tracker. At minimum
        // the blocklist mechanics ran without panicking and states are
        // consistent booleans.
        for s in &states {
            let _ = s.blocked;
        }
    }

    #[test]
    fn exact_solver_agrees_with_greedy_on_easy_instance() {
        let clients = mk_clients(9, 3, 50);
        let states = vec![ClientRoundState::default(); 9];
        let domains = mk_domains(3, 800.0, 120);
        let (fcb, snow) = full_forecasts(&clients, &domains, 60);
        let ctx = mk_ctx(&clients, &states, &domains, fcb.view(), &snow, 3, 60);
        let mut rng = Rng::new(0);
        let mut g = FedZero::new(SolverKind::Greedy);
        let mut e = FedZero::new(SolverKind::Exact);
        let dg = g.select(&ctx, &mut rng);
        let de = e.select(&ctx, &mut rng);
        assert_eq!(dg.expected_duration, de.expected_duration);
        assert_eq!(dg.clients.len(), de.clients.len());
    }
}
