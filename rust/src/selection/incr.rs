//! Incremental selection state — the persistent structure that makes
//! FedZero's *dark-period polling loop* O(D) per idle step and its
//! per-`select()` filter precompute incremental (ROADMAP: "Sub-O(C)
//! dark-period polling", "Incremental `d_reach`").
//!
//! The scheduler spends most simulated time polling `select()` between
//! rounds. PRs 1–3 made a poll allocation-free and the forecast window
//! incremental, but two O(C·…) costs remained in the loop:
//!
//! * the dark-period quick gate scanned all C clients per idle step;
//! * the per-client line-11 reachability curve (`d_reach`) was recomputed
//!   from scratch per `select()` — O(C·d_max) whenever the gate passed.
//!
//! [`IncrSelState`] is owned by the sim loop next to the
//! [`super::ring::ForecastRing`] and is patched in lockstep with it:
//!
//! * **Per-domain client index + dirty-domain tracking** — clients are
//!   grouped by domain once per rebuild (CSR layout). On
//!   [`IncrSelState::advance`] only *dirty* domains touch their clients:
//!   a domain is dirty when its evicted window column had energy > 0
//!   (every prefix sum of its clients changed), when its appended column
//!   has energy > 0 (new crossings possible at the window tail), or when
//!   the window tail just completed a bucket that holds some of its
//!   energy (the walk's geometry for that bucket changed, see below). A
//!   FULLY DARK window makes every domain clean, so an idle step touches
//!   only the D domain counters and **no client state at all** —
//!   property- and unit-tested via [`IncrSelState::last_advance_touched`].
//! * **Ring-patched `d_reach` over √d_max buckets** — window columns are
//!   partitioned into buckets of `B = ⌈√d_max⌉` columns aligned to the
//!   *forecast anchor* (absolute step = anchor + phase + offset), so a
//!   bucket's member columns never change as the window slides. Per
//!   client the state holds one f64 left-fold term sum per bucket
//!   (`bsum`); per advance only the tail bucket gains one term (a single
//!   gated add per client of a lit domain), and re-deriving a client's
//!   reach walks O(√d_max) bucket sums instead of O(d_max) columns.
//! * **Eligibility aggregates** — `elig_fin[p]` counts live clients of
//!   domain p whose reach lies inside the window, maintained on every
//!   reach transition, so the dark-period gate
//!   ([`IncrSelState::quick_eligible_count`]) is a pure O(D) counter
//!   sum. The per-probe `eligible_count(d)` of the arena becomes an O(1)
//!   lookup into a cumulative histogram built from these reaches once
//!   per `select()` (O(C + d_max) integer work, no forecast reads).
//!
//! ## The canonical accumulation order (f64 rationale)
//!
//! f64 addition is not associative, so "the sum of the first d terms"
//! depends on the order of operations. The pre-incremental code defined
//! the line-11 curve as a plain left fold; a bucket-patched structure
//! cannot reproduce a plain left fold bitwise (it adds whole-bucket
//! subtotals). Instead of chasing an impossible equivalence, this module
//! *defines* the canonical order — [`reach_walk`]: head columns up to
//! the first anchor-aligned bucket boundary term by term, then one add
//! per full bucket subtotal, switching to term-by-term for the remainder
//! of the window at the bucket where the crossing falls, tail columns
//! term by term — and EVERY layer (the fresh [`super::arena::SelArena`]
//! build, [`super::SelectionContext::reachable_min`], and the
//! incremental patches here) evaluates exactly this walk on exactly the
//! same `f32`-quantised inputs. Fresh and incremental state are then
//! bit-equivalent by construction (property-tested below, gated end to
//! end in `benches/endtoend.rs`).
//!
//! Why the patches preserve the walk bit for bit:
//!
//! * terms are `min(spare_t, energy_t/δ)` gated on `energy_t > 0`; a
//!   zero-energy column contributes `+0.0`, and `x + 0.0 == x` bitwise
//!   for every non-negative f64 — so skipping dark columns (and whole
//!   dark domains) is exact, and the head region's fold is unchanged
//!   when a zero-term column is evicted;
//! * bucket subtotals are only ever *extended at the tail* (same left
//!   fold the fresh build performs) and are read only for buckets fully
//!   inside the window, whose member columns are immutable;
//! * the only geometry change the slide causes is a tail bucket becoming
//!   full (its subtotal replaces term-by-term evaluation mid-walk, which
//!   can flip a knife-edge `cum >= need` comparison) — exactly then, the
//!   domains with energy in that bucket re-derive their clients. The
//!   head-side transition (a full bucket becoming the partial head) is
//!   exact without re-derivation: the head region starts from
//!   `cum = 0.0`, where one subtotal add and the term fold are the same
//!   float sequence.
//!
//! Liveness (`!blocked && σ > 0`) is snapshotted at
//! [`IncrSelState::rebuild`]: the engine rebuilds after every executed
//! round (states only mutate at round boundaries; the σ refresh is
//! idempotent across consecutive idle polls), so advances always run
//! under an unchanged snapshot.

use super::ring::{FcSource, FcView, ForecastRing};
use crate::client::ClientInfo;
use crate::selection::ClientRoundState;
use crate::util::par;
use crate::util::par::thresholds::{MIN_FILL_ROWS, REDERIVE_CLIENTS};

/// Borrowed view of the per-client scalar snapshot captured at
/// [`IncrSelState::rebuild`] (ROADMAP "incremental arena scalars"): the
/// constants (domain, δ, m_min, m_max) plus the per-round mutables
/// (σ, liveness). σ only changes when a round executes, and the engine
/// rebuilds this state right after every executed round's σ refresh —
/// so between rebuilds the snapshot equals the live `ClientRoundState`
/// values and [`super::arena::SelArena::build`] borrows it instead of
/// copying five O(C) vectors per `select()`.
#[derive(Clone, Copy)]
pub struct ScalarTable<'a> {
    pub domain: &'a [usize],
    pub sigma: &'a [f64],
    pub delta: &'a [f64],
    pub m_min: &'a [f64],
    pub m_max: &'a [f64],
    pub live: &'a [bool],
}

/// Bucket width of the √d_max decomposition: ⌈√d_max⌉ (integer-exact).
pub fn bucket_width(d_max: usize) -> usize {
    let mut b = 1usize;
    while b * b < d_max {
        b += 1;
    }
    b
}

/// One gated term of the line-11 standalone curve: what client spare and
/// domain energy allow at window offset `t`, in batches. Zero-energy
/// columns are exactly `+0.0` regardless of spare (which is what lets
/// dark columns — whose spare may be lazily deferred by the ring — never
/// be read).
#[inline]
fn term_at(spare: &[f32], energy: &[f32], delta: f64, t: usize) -> f64 {
    let e = energy[t];
    if e > 0.0 {
        (spare[t] as f64).min(e as f64 / delta)
    } else {
        0.0
    }
}

/// THE canonical line-11 reachability evaluation (see the module docs
/// for the order contract): smallest 1-based duration `d` at which the
/// cumulative standalone batch curve reaches `need`, or `usize::MAX` if
/// it never does within the window. `phase` is the window's advance
/// count since its forecast anchor (bucket boundaries sit at absolute
/// steps divisible by `bucket`); `bsum(t)` must return the left-fold
/// subtotal of the full bucket starting at window offset `t`.
pub fn reach_walk(
    spare: &[f32],
    energy: &[f32],
    delta: f64,
    need: f64,
    phase: usize,
    bucket: usize,
    mut bsum: impl FnMut(usize) -> f64,
) -> usize {
    let d_max = spare.len();
    debug_assert_eq!(energy.len(), d_max);
    debug_assert!(bucket >= 1);
    let mut cum = 0.0f64;
    // head region: up to the first anchor-aligned bucket boundary
    let head_len = match phase % bucket {
        0 => 0,
        r => (bucket - r).min(d_max),
    };
    for t in 0..head_len {
        cum += term_at(spare, energy, delta, t);
        if cum >= need {
            return t + 1;
        }
    }
    // full buckets: one add per subtotal while the crossing is not here
    let mut t = head_len;
    while t + bucket <= d_max {
        let bs = bsum(t);
        if cum + bs >= need {
            // the crossing falls in (or knife-edge ties) this bucket:
            // term-by-term for the remainder of the window
            for tt in t..d_max {
                cum += term_at(spare, energy, delta, tt);
                if cum >= need {
                    return tt + 1;
                }
            }
            return usize::MAX;
        }
        cum += bs;
        t += bucket;
    }
    // tail region
    for tt in t..d_max {
        cum += term_at(spare, energy, delta, tt);
        if cum >= need {
            return tt + 1;
        }
    }
    usize::MAX
}

/// [`reach_walk`] with bucket subtotals computed on the fly (the fresh
/// path used by `SelArena::build` and `SelectionContext::reachable_min`
/// when no incremental state is attached). The subtotal fold is the same
/// gated left fold the incremental patches maintain, so the two paths
/// are bit-equivalent.
pub fn reach_fresh(
    spare: &[f32],
    energy: &[f32],
    delta: f64,
    need: f64,
    phase: usize,
    bucket: usize,
) -> usize {
    reach_walk(spare, energy, delta, need, phase, bucket, |t| {
        let mut acc = 0.0f64;
        for k in t..t + bucket {
            let e = energy[k];
            if e > 0.0 {
                acc += (spare[k] as f64).min(e as f64 / delta);
            }
        }
        acc
    })
}

/// The persistent incremental selection state (see the module docs).
/// Owned by the simulation loop next to the [`ForecastRing`]; rebuilt
/// whenever the ring re-anchors, advanced in lockstep with it.
#[derive(Debug, Default)]
pub struct IncrSelState {
    built: bool,
    d_max: usize,
    /// √d_max bucket width (see [`bucket_width`])
    bucket: usize,
    /// bucket slots per row (window spans ≤ d_max/bucket + 2 buckets)
    n_slots: usize,
    n_clients: usize,
    n_domains: usize,
    /// advances since the anchor — mirrors the ring's `FcView::phase`
    k: usize,
    // --- per-client scalars captured at rebuild (see [`ScalarTable`]) ---
    domain: Vec<usize>,
    delta: Vec<f64>,
    /// m_min — `need <= 0` clients are "trivially reachable" and tracked
    /// via `n_triv`/`first_e_abs` instead of `reach_abs`
    need: Vec<f64>,
    /// m_max (constant; part of the borrowed scalar table)
    m_max: Vec<f64>,
    /// σ snapshot (valid between rebuilds; the engine rebuilds after the
    /// round-end σ refresh)
    sigma: Vec<f64>,
    /// liveness snapshot: `!blocked && σ > 0` (constant between rebuilds)
    live: Vec<bool>,
    /// CSR client-by-domain index: clients of domain p are
    /// `dom_clients[dom_start[p]..dom_start[p+1]]`
    dom_start: Vec<usize>,
    dom_clients: Vec<usize>,
    // --- incremental structures ---
    /// [n_clients × n_slots] full-bucket term subtotals (slot =
    /// bucket_index % n_slots); valid iff the matching `binit` entry
    /// names the bucket — otherwise the bucket held no energy for the
    /// client's domain and its subtotal is exactly +0.0
    bsum: Vec<f64>,
    /// [n_domains × n_slots] bucket index whose subtotals currently
    /// occupy the slot for this domain's clients; u64::MAX = none
    binit: Vec<u64>,
    /// [n_domains × n_slots] count of in-window columns with energy > 0
    /// per bucket (integer-exact, like the ring's liveness counters)
    ecount: Vec<u32>,
    /// per-client anchor-relative reach: `phase_at_crossing + d` where
    /// `d` is the canonical walk result, or usize::MAX when the curve
    /// never reaches `need` inside the window. Window-relative reach at
    /// phase k is `reach_abs - k`. Maintained only for `need > 0`.
    reach_abs: Vec<usize>,
    /// per-domain: live `need > 0` clients with in-window reach
    elig_fin: Vec<u32>,
    /// per-domain: live `need <= 0` clients (eligible iff the domain has
    /// any energy within the first d columns)
    n_triv: Vec<u32>,
    /// per-domain: anchor-relative index of the first window column with
    /// energy > 0 (usize::MAX = fully dark domain)
    first_e_abs: Vec<usize>,
    /// scratch: evicted energy column captured before the ring advances
    evict_scratch: Vec<f32>,
    /// scratch: (client, domain) re-derivation candidates of the current
    /// advance (reused across advances; see [`Self::advance`])
    cand_scratch: Vec<(u32, u32)>,
    /// scratch: walk results parallel to `cand_scratch` (reused so lit
    /// advances stay allocation-free in steady state)
    walk_scratch: Vec<usize>,
    /// instrumentation: per-client operations performed by the last
    /// `advance` (bucket appends + reach re-derivations). 0 for a fully
    /// dark step — the O(D) guarantee the tests pin down.
    last_touched: usize,
    /// dirty-client count at which the re-derivation walks fan out
    /// across threads; 0 (the `Default`) means
    /// `thresholds::REDERIVE_CLIENTS`. Tests pin 1 / usize::MAX to force
    /// both paths — results are bit-identical either way.
    pub rederive_par_min: usize,
}

impl IncrSelState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_built(&self) -> bool {
        self.built
    }

    /// Advances since the anchor (== the window view's `phase`).
    pub fn phase(&self) -> usize {
        self.k
    }

    pub fn d_max(&self) -> usize {
        self.d_max
    }

    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    /// Per-client operations performed by the last [`Self::advance`]
    /// (dirty-domain work). Exactly 0 for a fully dark advance.
    pub fn last_advance_touched(&self) -> usize {
        self.last_touched
    }

    /// The per-client scalar snapshot captured at the last rebuild —
    /// borrowed by `SelArena::build` instead of re-copying per select.
    pub fn scalar_table(&self) -> ScalarTable<'_> {
        ScalarTable {
            domain: &self.domain,
            sigma: &self.sigma,
            delta: &self.delta,
            m_min: &self.need,
            m_max: &self.m_max,
            live: &self.live,
        }
    }

    /// Window-relative effective reach of client `i`: the smallest
    /// duration d at which it passes ALL of the line-6/8/11 eligibility
    /// filters (blocklist, σ > 0, domain energy within d, standalone
    /// reachability within d); usize::MAX = not eligible at any d. For
    /// `need > 0` the domain-energy condition is implied by the curve
    /// crossing (a positive term needs a positive energy column), so
    /// this is exactly the canonical walk result.
    #[inline]
    pub fn eff_rel(&self, i: usize) -> usize {
        if !self.live[i] {
            return usize::MAX;
        }
        if self.need[i] > 0.0 {
            match self.reach_abs[i] {
                usize::MAX => usize::MAX,
                a => a - self.k,
            }
        } else {
            match self.first_e_abs[self.domain[i]] {
                usize::MAX => usize::MAX,
                a => a - self.k + 1,
            }
        }
    }

    /// The d_max eligibility count in O(D): per-domain counter sums, no
    /// client is touched. Equals `SelArena::quick_eligible_count` on the
    /// same context (KEEP IN SYNC — property-tested in this module and
    /// in `selection::arena`). `first_e_abs[p] != MAX` is exactly the
    /// ring's integer domain-liveness condition.
    pub fn quick_eligible_count(&self) -> usize {
        let mut total = 0usize;
        for p in 0..self.n_domains {
            total += self.elig_fin[p] as usize;
            if self.n_triv[p] > 0 && self.first_e_abs[p] != usize::MAX {
                total += self.n_triv[p] as usize;
            }
        }
        total
    }

    /// Snapshot client constants + liveness and derive every incremental
    /// structure from the (anchor-fresh) window. O(C·d_max) for lit
    /// domains — the cost one historical `SelArena::build` paid on EVERY
    /// select — and O(C + D·√d_max) when the window is fully dark.
    /// Called by the engine whenever the ring re-anchors (after every
    /// executed round); client walks fan out across threads at scale.
    pub fn rebuild(
        &mut self,
        clients: &[ClientInfo],
        states: &[ClientRoundState],
        fc: FcView<'_>,
    ) {
        let d_max = fc.d_max();
        assert!(d_max >= 1, "rebuild on an empty window");
        assert_eq!(
            fc.phase(),
            0,
            "incremental state must be rebuilt at a fresh anchor"
        );
        assert_eq!(clients.len(), states.len());
        assert_eq!(clients.len(), fc.n_clients());
        let b = bucket_width(d_max);
        let n_slots = d_max / b + 2;
        let n_clients = clients.len();
        let n_domains = fc.n_domains();
        self.d_max = d_max;
        self.bucket = b;
        self.n_slots = n_slots;
        self.n_clients = n_clients;
        self.n_domains = n_domains;
        self.k = 0;
        self.last_touched = 0;

        self.domain.clear();
        self.delta.clear();
        self.need.clear();
        self.m_max.clear();
        self.sigma.clear();
        self.live.clear();
        for (i, c) in clients.iter().enumerate() {
            self.domain.push(c.domain);
            self.delta.push(c.delta());
            self.need.push(c.m_min);
            self.m_max.push(c.m_max);
            self.sigma.push(states[i].sigma);
            self.live.push(!states[i].blocked && states[i].sigma > 0.0);
        }

        // CSR domain → clients (counting sort; stable in client order)
        self.dom_start.clear();
        self.dom_start.resize(n_domains + 1, 0);
        for &p in &self.domain {
            self.dom_start[p + 1] += 1;
        }
        for p in 0..n_domains {
            self.dom_start[p + 1] += self.dom_start[p];
        }
        self.dom_clients.clear();
        self.dom_clients.resize(n_clients, 0);
        {
            let mut cursor = self.dom_start.clone();
            for (i, &p) in self.domain.iter().enumerate() {
                self.dom_clients[cursor[p]] = i;
                cursor[p] += 1;
            }
        }

        // per-domain energy buckets + first lit column
        self.ecount.clear();
        self.ecount.resize(n_domains * n_slots, 0);
        self.binit.clear();
        self.binit.resize(n_domains * n_slots, u64::MAX);
        self.first_e_abs.clear();
        self.first_e_abs.resize(n_domains, usize::MAX);
        for p in 0..n_domains {
            let row = fc.energy_row(p);
            for (t, &e) in row.iter().enumerate() {
                if e > 0.0 {
                    let bu = t / b; // phase 0: offset == anchor-relative
                    self.ecount[p * n_slots + bu % n_slots] += 1;
                    if self.first_e_abs[p] == usize::MAX {
                        self.first_e_abs[p] = t;
                    }
                }
            }
            for bu in 0..=(d_max - 1) / b {
                if self.ecount[p * n_slots + bu % n_slots] > 0 {
                    self.binit[p * n_slots + bu % n_slots] = bu as u64;
                }
            }
        }

        // per-client bucket subtotals: the same gated left fold the
        // advance-time appends extend. Rows of dark domains are skipped
        // (their slots are sentinel-guarded and read as +0.0).
        if self.bsum.len() != n_clients * n_slots {
            self.bsum.clear();
            self.bsum.resize(n_clients * n_slots, 0.0);
        }
        {
            let domain = &self.domain;
            let delta = &self.delta;
            let first_e_abs = &self.first_e_abs;
            let binit = &self.binit;
            par::par_fill_rows(&mut self.bsum, n_slots, MIN_FILL_ROWS, |i, row| {
                let p = domain[i];
                if first_e_abs[p] == usize::MAX {
                    return;
                }
                let srow = fc.spare_row(i);
                let erow = fc.energy_row(p);
                let dl = delta[i];
                for bu in 0..=(d_max - 1) / b {
                    if binit[p * n_slots + bu % n_slots] != bu as u64 {
                        continue;
                    }
                    let lo = bu * b;
                    let hi = ((bu + 1) * b).min(d_max);
                    let mut acc = 0.0f64;
                    for t in lo..hi {
                        let e = erow[t];
                        if e > 0.0 {
                            acc += (srow[t] as f64).min(e as f64 / dl);
                        }
                    }
                    row[bu % n_slots] = acc;
                }
            });
        }

        // per-client reach (need > 0 only; dark domains stay MAX)
        self.reach_abs.clear();
        self.reach_abs.resize(n_clients, usize::MAX);
        {
            let domain = &self.domain;
            let delta = &self.delta;
            let need = &self.need;
            let first_e_abs = &self.first_e_abs;
            let binit = &self.binit;
            let bsum = &self.bsum;
            par::par_fill_rows(&mut self.reach_abs, 1, MIN_FILL_ROWS, |i, out| {
                out[0] = usize::MAX;
                let p = domain[i];
                if need[i] <= 0.0 || first_e_abs[p] == usize::MAX {
                    return;
                }
                let r = reach_walk(
                    fc.spare_row(i),
                    fc.energy_row(p),
                    delta[i],
                    need[i],
                    0,
                    b,
                    |t| {
                        let bu = t / b;
                        if binit[p * n_slots + bu % n_slots] == bu as u64 {
                            bsum[i * n_slots + bu % n_slots]
                        } else {
                            0.0
                        }
                    },
                );
                if r != usize::MAX {
                    out[0] = r; // phase 0: abs == window-relative
                }
            });
        }

        // eligibility aggregates
        self.elig_fin.clear();
        self.elig_fin.resize(n_domains, 0);
        self.n_triv.clear();
        self.n_triv.resize(n_domains, 0);
        for i in 0..n_clients {
            if !self.live[i] {
                continue;
            }
            if self.need[i] <= 0.0 {
                self.n_triv[self.domain[i]] += 1;
            } else if self.reach_abs[i] != usize::MAX {
                self.elig_fin[self.domain[i]] += 1;
            }
        }
        self.built = true;
    }

    /// Advance the ring one slot and patch every incremental structure.
    /// A fully dark step is O(D) — only the per-domain counters are
    /// touched; lit/dirty domains pay one gated add per client (tail
    /// append) plus O(√d_max)-walk re-derivations for the clients whose
    /// reach may have moved (see the module docs for the dirty rules).
    ///
    /// §Perf (ROADMAP "parallel dirty-domain re-derivation"): the
    /// advance is three phases. Phase 1 (serial, O(D) + one gated add
    /// per lit-domain client) updates the integer counters and appends
    /// the tail terms, and collects the re-derivation candidates in
    /// (domain, CSR) order — the exact order the historical serial loop
    /// visited them. Phase 2 runs the candidates' canonical walks in
    /// parallel (`util::par::par_fill_rows` into a reused result
    /// scratch, so lit advances allocate nothing in steady state): each
    /// walk is a pure read of the
    /// window, `bsum`/`binit` and the per-client constants, all frozen
    /// during the phase, so chunking cannot change any result. Phase 3
    /// applies the reach transitions and eligibility counters serially
    /// in candidate order. Interleaving per domain (the historical
    /// shape) and phase-splitting are equivalent because appends only
    /// touch the appending domain's rows and applications only touch
    /// state no walk reads — bit-equivalence is property-tested with
    /// both fan-out gates forced.
    pub fn advance(&mut self, ring: &mut ForecastRing, src: &impl FcSource) {
        assert!(self.built, "advance() before rebuild()");
        assert!(ring.is_built());
        debug_assert_eq!(ring.window_start() - ring.anchor(), self.k);
        let d_max = self.d_max;
        let b = self.bucket;
        let ns = self.n_slots;
        let k_old = self.k;
        let evict_abs = k_old;
        let append_abs = k_old + d_max;

        // capture the evicted energy column before the ring overwrites it
        self.evict_scratch.clear();
        {
            let v = ring.view();
            debug_assert_eq!(v.n_domains(), self.n_domains);
            for p in 0..self.n_domains {
                self.evict_scratch.push(v.energy_row(p)[0]);
            }
        }
        ring.advance(src);
        self.k = k_old + 1;

        let fcv = ring.view();
        let b_ev = evict_abs / b;
        let b_ap = append_abs / b;
        let new_bucket = append_abs % b == 0;
        // did this append COMPLETE bucket b_ap? (its last column is
        // append_abs ⇔ the next column starts a new bucket) — the walk
        // now reads b_ap via its subtotal, a geometry change that needs
        // re-derivation for domains with energy in it (module docs)
        let promoted = (append_abs + 1) % b == 0;
        let mut touched = 0usize;
        let mut cand = std::mem::take(&mut self.cand_scratch);
        cand.clear();

        for p in 0..self.n_domains {
            let e_old = self.evict_scratch[p];
            let e_new = fcv.energy_row(p)[d_max - 1];
            // integer bucket counters (exact, every advance, O(1))
            if e_old > 0.0 {
                self.ecount[p * ns + b_ev % ns] -= 1;
            }
            let ap_cnt = p * ns + b_ap % ns;
            if new_bucket {
                self.ecount[ap_cnt] = (e_new > 0.0) as u32;
            } else if e_new > 0.0 {
                self.ecount[ap_cnt] += 1;
            }
            // first lit column
            if e_new > 0.0 && self.first_e_abs[p] == usize::MAX {
                self.first_e_abs[p] = append_abs;
            }
            if e_old > 0.0 && self.first_e_abs[p] == evict_abs {
                let fe = self.scan_first_e(p, &fcv);
                self.first_e_abs[p] = fe;
            }

            let (cs, ce) = (self.dom_start[p], self.dom_start[p + 1]);
            // tail append: one gated add per client, only when the new
            // column actually carries energy (a zero term is a bitwise
            // no-op, so clean domains skip their clients entirely)
            if e_new > 0.0 {
                let bidx = p * ns + b_ap % ns;
                let fresh_bucket = self.binit[bidx] != b_ap as u64;
                if fresh_bucket {
                    self.binit[bidx] = b_ap as u64;
                }
                let slot = b_ap % ns;
                for j in cs..ce {
                    let i = self.dom_clients[j];
                    let term =
                        (fcv.spare_row(i)[d_max - 1] as f64).min(e_new as f64 / self.delta[i]);
                    let cell = &mut self.bsum[i * ns + slot];
                    if fresh_bucket {
                        *cell = term;
                    } else {
                        *cell += term;
                    }
                    touched += 1;
                }
            }

            // reach re-derivation candidates (dirty rules, module docs):
            //  * evicted energy > 0     → every prefix changed: all clients
            //  * promoted lit bucket    → walk geometry changed: all clients
            //  * appended energy > 0    → only never-reaching clients can
            //                             gain a crossing (at the new tail)
            let full_rederive = e_old > 0.0
                || (promoted && self.ecount[p * ns + b_ap % ns] > 0);
            if full_rederive {
                for j in cs..ce {
                    cand.push((self.dom_clients[j] as u32, p as u32));
                }
            } else if e_new > 0.0 {
                for j in cs..ce {
                    let i = self.dom_clients[j];
                    if self.reach_abs[i] == usize::MAX && self.need[i] > 0.0 {
                        cand.push((i as u32, p as u32));
                    }
                }
            }
        }
        touched += cand.len();

        // phase 2: the candidates' canonical walks, independent and
        // read-only — fanned out across workers at scale
        let min_par = match self.rederive_par_min {
            0 => REDERIVE_CLIENTS,
            m => m,
        };
        let mut new_abs = std::mem::take(&mut self.walk_scratch);
        new_abs.clear();
        new_abs.resize(cand.len(), usize::MAX);
        {
            let b = self.bucket;
            let ns = self.n_slots;
            let k = self.k;
            let binit = &self.binit;
            let bsum = &self.bsum;
            let need = &self.need;
            let delta = &self.delta;
            let cand = &cand;
            par::par_fill_rows(&mut new_abs, 1, min_par, |j, out| {
                let (i, p) = (cand[j].0 as usize, cand[j].1 as usize);
                if need[i] <= 0.0 {
                    return; // trivially reachable (n_triv): stays MAX
                }
                let r = reach_walk(
                    fcv.spare_row(i),
                    fcv.energy_row(p),
                    delta[i],
                    need[i],
                    k,
                    b,
                    |t| {
                        let bu = (k + t) / b;
                        if binit[p * ns + bu % ns] == bu as u64 {
                            bsum[i * ns + bu % ns]
                        } else {
                            0.0
                        }
                    },
                );
                if r != usize::MAX {
                    out[0] = k + r;
                }
            });
        }

        // phase 3: serial reach/counter application in candidate order
        for (j, &(i, p)) in cand.iter().enumerate() {
            self.apply_reach(i as usize, p as usize, new_abs[j]);
        }
        self.cand_scratch = cand;
        self.walk_scratch = new_abs;
        self.last_touched = touched;
    }

    /// Fold one re-derived walk result into `reach_abs` and the
    /// per-domain eligibility counter (serial application phase).
    fn apply_reach(&mut self, i: usize, p: usize, new_abs: usize) {
        if self.need[i] <= 0.0 {
            return; // trivially-reachable clients live in n_triv
        }
        let old = self.reach_abs[i];
        if self.live[i] && (old == usize::MAX) != (new_abs == usize::MAX) {
            if new_abs == usize::MAX {
                self.elig_fin[p] -= 1;
            } else {
                self.elig_fin[p] += 1;
            }
        }
        self.reach_abs[i] = new_abs;
    }

    /// First in-window column with energy > 0 for domain `p`, in
    /// anchor-relative terms — O(√d_max) via the bucket counters.
    fn scan_first_e(&self, p: usize, fcv: &FcView<'_>) -> usize {
        let b = self.bucket;
        let ns = self.n_slots;
        let k = self.k;
        let d = self.d_max;
        let row = fcv.energy_row(p);
        let b_lo = k / b;
        let b_hi = (k + d - 1) / b;
        for bu in b_lo..=b_hi {
            if self.ecount[p * ns + bu % ns] == 0 {
                continue;
            }
            let lo = (bu * b).max(k);
            let hi = ((bu + 1) * b).min(k + d);
            for c in lo..hi {
                if row[c - k] > 0.0 {
                    return c;
                }
            }
        }
        usize::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{ClientProfile, DeviceType, ModelKind};
    use crate::selection::arena::SelArena;
    use crate::selection::ring::{FcBuffers, SeriesSource};
    use crate::selection::SelectionContext;
    use crate::trace::forecast::SeriesForecaster;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn mk_clients(
        rng: &mut Rng,
        n: usize,
        n_domains: usize,
        random_domains: bool,
    ) -> Vec<ClientInfo> {
        (0..n)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::ALL[rng.below(3)],
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                let dom = if random_domains { rng.below(n_domains) } else { i % n_domains };
                ClientInfo::new(i, dom, p, (0..rng.range(1, 60)).collect(), 10)
            })
            .collect()
    }

    fn mk_source(
        rng: &mut Rng,
        clients: &[ClientInfo],
        n_domains: usize,
        horizon: usize,
        dark: bool,
        realistic: bool,
    ) -> SeriesSource {
        let mk = |rng: &mut Rng, series: Vec<f64>| {
            if realistic {
                SeriesForecaster::realistic(series, rng.next_u64(), 60.0)
            } else {
                SeriesForecaster::perfect(series)
            }
        };
        let energy = (0..n_domains)
            .map(|_| {
                let series: Vec<f64> = if dark {
                    vec![0.0; horizon]
                } else {
                    let base = rng.range_f64(0.0, 40.0);
                    let ph = rng.range_f64(0.0, 6.0);
                    (0..horizon)
                        .map(|t| (base * ((t as f64 / 9.0 + ph).sin())).max(0.0))
                        .collect()
                };
                mk(rng, series)
            })
            .collect();
        let caps: Vec<f64> = clients.iter().map(|c| c.capacity()).collect();
        let spare = caps
            .iter()
            .map(|&cap| {
                let series: Vec<f64> = (0..horizon)
                    .map(|_| cap * rng.range_f64(0.0, 1.3))
                    .collect();
                mk(rng, series)
            })
            .collect();
        SeriesSource { energy, spare, caps }
    }

    #[test]
    fn bucket_width_is_ceil_sqrt() {
        assert_eq!(bucket_width(1), 1);
        assert_eq!(bucket_width(2), 2);
        assert_eq!(bucket_width(4), 2);
        assert_eq!(bucket_width(5), 3);
        assert_eq!(bucket_width(9), 3);
        assert_eq!(bucket_width(60), 8);
        assert_eq!(bucket_width(1440), 38);
        for d in 1..2000 {
            let b = bucket_width(d);
            assert!(b * b >= d && (b - 1) * (b - 1) < d, "d={d} b={b}");
            assert!(b <= d);
        }
    }

    /// The tentpole invariant: after arbitrary advance sequences
    /// (including dark edges, bucket promotions, head wraparound, and a
    /// round-boundary re-anchor) the incremental state is bit-equal to a
    /// fresh `SelArena::build` over a fresh window — same per-client
    /// effective reach, same eligibility counts at EVERY duration, same
    /// quick gate.
    #[test]
    fn incremental_state_matches_fresh_arena_after_advances() {
        forall(20, |rng| {
            let n_domains = rng.range(1, 4);
            let n_clients = rng.range(3, 14);
            let d_max = rng.range(4, 32);
            let steps = rng.range(2 * d_max, 3 * d_max + 5);
            let horizon = d_max + steps + d_max + 10;
            let realistic = rng.bool(0.5);
            let mut clients = mk_clients(rng, n_clients, n_domains, true);
            // exercise the trivially-reachable (need <= 0) path too
            if rng.bool(0.4) {
                clients[0].m_min = 0.0;
            }
            let mut states = vec![ClientRoundState::default(); n_clients];
            for s in states.iter_mut() {
                s.blocked = rng.bool(0.2);
                s.sigma = if s.blocked { 0.0 } else { rng.range_f64(0.0, 8.0) };
            }
            let src = mk_source(rng, &clients, n_domains, horizon, false, realistic);
            let spare_now: Vec<f64> = clients.iter().map(|c| c.capacity()).collect();

            let mut ring = ForecastRing::new();
            let mut incr = IncrSelState::new();
            let mut anchor = 0usize;
            ring.rebuild(&src, anchor, d_max);
            incr.rebuild(&clients, &states, ring.view());
            // re-anchor once mid-run, like the engine does after a round
            let reanchor_at = rng.range(1, steps);

            for step in 1..=steps {
                if step == reanchor_at {
                    anchor += step;
                    ring.rebuild(&src, anchor, d_max);
                    incr.rebuild(&clients, &states, ring.view());
                }
                incr.advance(&mut ring, &src);
                let t = ring.window_start();
                let fresh = FcBuffers::from_source(&src, anchor, t, d_max);
                let ctx_fresh = SelectionContext {
                    now: t,
                    n: 1,
                    d_max,
                    clients: &clients,
                    states: &states,
                    domains: &[],
                    fc: fresh.view(),
                    incr: None,
                    spare_now: &spare_now,
                };
                let ctx_incr = SelectionContext {
                    now: t,
                    n: 1,
                    d_max,
                    clients: &clients,
                    states: &states,
                    domains: &[],
                    fc: ring.view(),
                    incr: Some(&incr),
                    spare_now: &spare_now,
                };
                let a_fresh = SelArena::build(&ctx_fresh);
                let a_incr = SelArena::build(&ctx_incr);
                for i in 0..n_clients {
                    assert_eq!(
                        a_incr.eff_reach(i),
                        a_fresh.eff_reach(i),
                        "eff reach diverged: client {i} at step {step} (t={t})"
                    );
                }
                for d in 1..=d_max {
                    assert_eq!(
                        a_incr.eligible_count(d),
                        a_fresh.eligible_count(d),
                        "eligible_count({d}) diverged at step {step}"
                    );
                }
                // the borrowed scalar table must hand probes the same
                // per-client values the fresh O(C) copy produced
                let mut s_fresh = crate::selection::arena::ProbeScratch::new();
                let mut s_incr = crate::selection::arena::ProbeScratch::new();
                let ok_f = a_fresh.fill_probe(&mut s_fresh, d_max);
                let ok_i = a_incr.fill_probe(&mut s_incr, d_max);
                assert_eq!(ok_f, ok_i, "probe feasibility diverged at {step}");
                if ok_f {
                    assert_eq!(s_fresh.ids, s_incr.ids);
                    let (inst_f, inst_i) = (s_fresh.instance(), s_incr.instance());
                    for (a, b) in inst_f.clients.iter().zip(inst_i.clients.iter()) {
                        assert_eq!(a.domain, b.domain);
                        assert_eq!(a.sigma.to_bits(), b.sigma.to_bits());
                        assert_eq!(a.delta.to_bits(), b.delta.to_bits());
                        assert_eq!(a.m_min.to_bits(), b.m_min.to_bits());
                        assert_eq!(a.m_max.to_bits(), b.m_max.to_bits());
                    }
                }
                assert_eq!(
                    SelArena::quick_eligible_count(&ctx_incr),
                    SelArena::quick_eligible_count(&ctx_fresh),
                    "quick gate diverged at step {step}"
                );
                assert_eq!(
                    incr.quick_eligible_count(),
                    a_fresh.eligible_count(d_max),
                    "O(D) gate != fresh arena count at step {step}"
                );
            }
        });
    }

    /// The parallel dirty-domain re-derivation satellite: advancing with
    /// the walk fan-out forced ON must be bit-equivalent to forced-serial
    /// advances — same reaches, same counters, same touch counts — over
    /// arbitrary windows including dark edges and re-anchors.
    #[test]
    fn parallel_rederive_matches_serial_bitwise() {
        forall(12, |rng| {
            let n_domains = rng.range(1, 4);
            let n_clients = rng.range(4, 24);
            let d_max = rng.range(4, 32);
            let steps = rng.range(d_max, 2 * d_max + 5);
            let horizon = d_max + steps + d_max + 10;
            let clients = mk_clients(rng, n_clients, n_domains, true);
            let mut states = vec![ClientRoundState::default(); n_clients];
            for s in states.iter_mut() {
                s.blocked = rng.bool(0.2);
                s.sigma = if s.blocked { 0.0 } else { rng.range_f64(0.0, 8.0) };
            }
            let src =
                mk_source(rng, &clients, n_domains, horizon, false, rng.bool(0.5));

            let mut ring_ser = ForecastRing::new();
            let mut ring_par = ForecastRing::new();
            let mut ser = IncrSelState::new();
            let mut par_ = IncrSelState::new();
            ser.rederive_par_min = usize::MAX; // never fan out
            par_.rederive_par_min = 1; // always fan out
            ring_ser.rebuild(&src, 0, d_max);
            ring_par.rebuild(&src, 0, d_max);
            ser.rebuild(&clients, &states, ring_ser.view());
            par_.rebuild(&clients, &states, ring_par.view());
            for step in 1..=steps {
                ser.advance(&mut ring_ser, &src);
                par_.advance(&mut ring_par, &src);
                assert_eq!(
                    ser.last_advance_touched(),
                    par_.last_advance_touched(),
                    "touch counts diverged at step {step}"
                );
                assert_eq!(
                    ser.quick_eligible_count(),
                    par_.quick_eligible_count(),
                    "quick gate diverged at step {step}"
                );
                for i in 0..n_clients {
                    assert_eq!(
                        ser.eff_rel(i),
                        par_.eff_rel(i),
                        "reach diverged: client {i} at step {step}"
                    );
                }
            }
        });
    }

    #[test]
    fn dark_advances_touch_no_clients() {
        // the acceptance criterion: a fully dark idle step performs NO
        // per-client work — only the D domain counters move
        let mut rng = Rng::new(7);
        let n_domains = 5;
        let clients = mk_clients(&mut rng, 40, n_domains, false);
        let states = vec![ClientRoundState::default(); clients.len()];
        let d_max = 24;
        let src = mk_source(&mut rng, &clients, n_domains, 400, true, false);
        let mut ring = ForecastRing::new();
        let mut incr = IncrSelState::new();
        ring.rebuild(&src, 0, d_max);
        incr.rebuild(&clients, &states, ring.view());
        for step in 1..=100 {
            incr.advance(&mut ring, &src);
            assert_eq!(
                incr.last_advance_touched(),
                0,
                "dark advance touched clients at step {step}"
            );
            assert_eq!(incr.quick_eligible_count(), 0);
        }
    }

    #[test]
    fn lit_advance_touches_only_dirty_domain_clients() {
        // one domain lit, the others dark: advance work is bounded by
        // the lit domain's client count (appends + re-derivations)
        let mut rng = Rng::new(11);
        let n_domains = 4;
        let clients = mk_clients(&mut rng, 32, n_domains, false);
        let states = vec![ClientRoundState::default(); clients.len()];
        let lit_clients = clients.iter().filter(|c| c.domain == 0).count();
        let d_max = 16;
        let horizon = 300;
        let caps: Vec<f64> = clients.iter().map(|c| c.capacity()).collect();
        let mut energy: Vec<SeriesForecaster> = (1..n_domains)
            .map(|_| SeriesForecaster::perfect(vec![0.0; horizon]))
            .collect();
        energy.insert(0, SeriesForecaster::perfect(vec![9.0; horizon]));
        let spare = caps
            .iter()
            .map(|&c| SeriesForecaster::perfect(vec![c; horizon]))
            .collect();
        let src = SeriesSource { energy, spare, caps };
        let mut ring = ForecastRing::new();
        let mut incr = IncrSelState::new();
        ring.rebuild(&src, 0, d_max);
        incr.rebuild(&clients, &states, ring.view());
        for step in 1..=60 {
            incr.advance(&mut ring, &src);
            assert!(
                incr.last_advance_touched() <= 2 * lit_clients,
                "advance touched {} ops for {lit_clients} lit clients (step {step})",
                incr.last_advance_touched()
            );
            assert!(incr.last_advance_touched() > 0, "lit advance did nothing");
        }
    }

    #[test]
    fn quick_count_tracks_dark_to_lit_transitions() {
        // a domain that turns on mid-horizon: the O(D) gate must flip
        // from 0 to the live client count exactly when the window sees
        // the first lit column, and back to 0 once it scrolls out
        let mut rng = Rng::new(3);
        let n_domains = 2;
        let clients = mk_clients(&mut rng, 10, n_domains, false);
        let states = vec![ClientRoundState::default(); clients.len()];
        let d_max = 8;
        let horizon = 120;
        let caps: Vec<f64> = clients.iter().map(|c| c.capacity()).collect();
        // lit only during [40, 50)
        let series: Vec<f64> = (0..horizon)
            .map(|t| if (40..50).contains(&t) { 500.0 } else { 0.0 })
            .collect();
        let energy = vec![
            SeriesForecaster::perfect(series),
            SeriesForecaster::perfect(vec![0.0; horizon]),
        ];
        let spare = caps
            .iter()
            .map(|&c| SeriesForecaster::perfect(vec![c; horizon]))
            .collect();
        let src = SeriesSource { energy, spare, caps };
        let mut ring = ForecastRing::new();
        let mut incr = IncrSelState::new();
        ring.rebuild(&src, 0, d_max);
        incr.rebuild(&clients, &states, ring.view());
        for step in 1..=horizon - d_max - 1 {
            incr.advance(&mut ring, &src);
            let t = ring.window_start();
            let window_lit = t < 50 && t + d_max > 40;
            let count = incr.quick_eligible_count();
            if !window_lit {
                assert_eq!(count, 0, "t={t}");
            } else if t + d_max > 40 && t <= 40 {
                // the lit stretch is fully ahead: every live domain-0
                // client with enough spare can reach m_min
                assert!(count > 0, "t={t}");
            }
        }
    }
}
