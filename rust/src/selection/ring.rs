//! The persistent forecast **ring-arena**: incremental window advance for
//! the simulation loop (ROADMAP: "Arena reuse across select() calls",
//! "100k-scale memory").
//!
//! FedZero's scheduler spends most simulated time *between* rounds: during
//! dark periods it polls `select()` every simulated minute. Before this
//! module, every poll re-materialised C + D forecast windows of length
//! d_max (each entry a hash-noise draw through the error model) and the
//! selection arena was rebuilt from scratch — O((C+D)·d_max) work per idle
//! step. Consecutive idle steps shift the window by exactly one slot, so
//! almost all of that work recomputed values already in memory.
//!
//! [`ForecastRing`] keeps the forecast window resident across steps:
//!
//! * **Mirrored ring rows** — energy is [domains × 2·d_max], spare is
//!   [clients × 2·d_max], `f32`. Each logical column is written twice, at
//!   physical position `h` and `h + d_max`, so the live window is always
//!   the contiguous slice `row[head .. head + d_max]` — probe code slices
//!   it exactly like a freshly built flat arena, no wraparound logic
//!   downstream. Advancing evicts the oldest column in place (the new
//!   column overwrites it) and bumps `head`; cost is one forecast fetch
//!   and two 4-byte writes per row: **O(C + D) per step**, independent of
//!   d_max — and while the window is FULLY dark the per-client spare
//!   append is deferred entirely (**O(D)**, no client row touched): a
//!   zero-energy column contributes a zero term to every selection
//!   filter regardless of spare, and the first lit append refetches all
//!   skipped columns before any reader can observe them (see
//!   `spare_stale_since`).
//! * **Exact domain-liveness counters** — the dark-period gate needs "does
//!   domain p have any excess energy in the window". A float window sum
//!   maintained by add/subtract would drift from a fresh left-fold and
//!   break bit-equivalence, so the ring instead counts columns `> 0` per
//!   domain (`nonzero`), updated with integer ±1 on evict/append. The
//!   count equals what a fresh build computes, exactly, forever.
//! * **`f32` storage** — forecasts carry ≲ 3 decimal digits of real
//!   information (the error model's σ saturates at 35%); `f32`'s 24-bit
//!   significand (relative error ≤ 6e-8) is far below forecast noise.
//!   At 100k clients × 1440 steps the mirrored f32 ring is the same
//!   footprint as the historical non-mirrored f64 arena — and the arena
//!   layer no longer copies rows at all, so peak forecast memory halves
//!   end to end. Values are widened to f64 at the solver boundary (every
//!   comparison/accumulation runs in f64, on identically-quantised
//!   inputs, so parallel/serial and ring/fresh paths agree bitwise).
//!
//! ## Issue-time anchoring
//!
//! The error model is issue-time dependent: `forecast(t0, t)` differs for
//! different `t0` (lead-time-dependent noise). A window that is advanced
//! one slot therefore keeps its **anchor** — the step the forecasts were
//! issued at — and fetches the appended column from the *same* issue time.
//! This mirrors how real forecast vendors work (forecasts are re-issued
//! periodically, not every minute) and is what makes incremental advance
//! well-defined: a ring advanced k times from anchor `a` is byte-identical
//! to [`FcBuffers::from_source`] built fresh at window start `a + k` with
//! anchor `a` (property-tested below and gated in the endtoend bench).
//! The engine re-anchors (full [`ForecastRing::rebuild`]) after every
//! executed round — the paper's "server queries the forecasters at round
//! start" — and advances during consecutive idle polls.
//!
//! ## Invariants
//!
//! * `head ∈ [0, d_max)`; window column k lives at `row[head + k]`; every
//!   physical pair `(j, j + d_max)` holds the same bits.
//! * `nonzero[p]` = |{k : energy_row(p)[k] > 0}| — maintained exactly
//!   (integer arithmetic), never recomputed from floats.
//! * All stored spare values are pre-clamped to the client's capacity by
//!   the [`FcSource`]; downstream code (reachability filters, arena,
//!   solvers) never clamps again, so every layer reads identical bits.

use crate::util::par;
use crate::util::par::thresholds::MIN_FILL_ROWS;

/// Where forecast values come from. `t0` is the issue (anchor) step, `t`
/// the absolute target step; implementations must be pure in `(t0, t)` so
/// ring advance and fresh builds fetch identical values.
pub trait FcSource: Sync {
    fn n_domains(&self) -> usize;
    fn n_clients(&self) -> usize;
    /// Forecast excess energy of domain `p` at step `t`, Wh/step.
    fn energy_at(&self, t0: usize, t: usize, p: usize) -> f64;
    /// Forecast spare capacity of client `i` at step `t`, batches/step,
    /// **pre-clamped to the client's capacity** (see module invariants).
    fn spare_at(&self, t0: usize, t: usize, i: usize) -> f64;
}

/// Borrowed, `Copy` view of one forecast window: per-domain energy rows
/// and per-client spare rows of length `d_max`, plus the exact
/// domain-liveness counters. Backed by either a [`ForecastRing`]
/// (mirrored rows, `stride = 2·d_max`, `head` moving) or [`FcBuffers`]
/// (flat rows, `stride = d_max`, `head = 0`) — row access is identical.
#[derive(Clone, Copy, Debug)]
pub struct FcView<'a> {
    n_domains: usize,
    n_clients: usize,
    d_max: usize,
    stride: usize,
    head: usize,
    /// advances since the forecast anchor (`window_start - anchor`) —
    /// the √d_max-bucket alignment of the canonical reachability walk
    /// (`selection::incr`) is anchored here, so a ring advanced k times
    /// and a fresh build at the same window agree on bucket boundaries.
    phase: usize,
    energy: &'a [f32],
    spare: &'a [f32],
    nonzero: &'a [u32],
}

impl<'a> FcView<'a> {
    /// A zero-window view for strategies with `needs_forecasts() == false`
    /// (they must not read rows; the engine skips filling the ring).
    pub const fn empty() -> FcView<'static> {
        FcView {
            n_domains: 0,
            n_clients: 0,
            d_max: 0,
            stride: 0,
            head: 0,
            phase: 0,
            energy: &[],
            spare: &[],
            nonzero: &[],
        }
    }

    #[inline]
    pub fn d_max(&self) -> usize {
        self.d_max
    }

    /// Advances since the forecast anchor (`window_start - anchor`); 0
    /// for a freshly (re)built window. See the field docs.
    #[inline]
    pub fn phase(&self) -> usize {
        self.phase
    }

    #[inline]
    pub fn n_domains(&self) -> usize {
        self.n_domains
    }

    #[inline]
    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    /// Domain `p`'s energy forecast for the window, Wh/step.
    #[inline]
    pub fn energy_row(&self, p: usize) -> &'a [f32] {
        let base = p * self.stride + self.head;
        &self.energy[base..base + self.d_max]
    }

    /// Client `i`'s spare-capacity forecast for the window, batches/step
    /// (pre-clamped to capacity at the source).
    #[inline]
    pub fn spare_row(&self, i: usize) -> &'a [f32] {
        let base = i * self.stride + self.head;
        &self.spare[base..base + self.d_max]
    }

    /// Does domain `p` forecast any excess energy within the window?
    /// Exact (integer counter), equal to `energy_row(p).iter().any(>0)`.
    #[inline]
    pub fn domain_alive(&self, p: usize) -> bool {
        self.nonzero[p] > 0
    }
}

/// The persistent ring (see module docs). Owned by the simulation loop;
/// `rebuild` re-issues all forecasts at a new anchor, `advance` shifts the
/// window one slot within the same anchor at O(C + D) cost.
#[derive(Debug, Default)]
pub struct ForecastRing {
    d_max: usize,
    n_domains: usize,
    n_clients: usize,
    built: bool,
    /// forecast issue step (fixed across advances)
    anchor: usize,
    /// absolute step of window column 0
    start: usize,
    /// physical index of window column 0 within each mirrored row
    head: usize,
    /// [n_domains × 2·d_max] mirrored energy rows, Wh/step
    energy: Vec<f32>,
    /// [n_clients × 2·d_max] mirrored spare rows, batches/step
    spare: Vec<f32>,
    /// exact count of window columns > 0 per domain
    nonzero: Vec<u32>,
    /// Σ nonzero — "is any domain lit anywhere in the window", exact
    nonzero_total: u64,
    /// §Perf (O(D) dark polling): while the window is FULLY dark, spare
    /// appends are skipped — a zero-energy column contributes a zero term
    /// to every selection filter regardless of spare, so no reader may
    /// observe the stale values (filters gate on energy > 0, and the
    /// solver only sees rows of clients whose domain is lit, which
    /// implies the window is lit and therefore fresh). This records the
    /// first skipped absolute column; the first lit append (the only way
    /// a dark window can become lit) catches all stale columns up before
    /// any spare value can be read.
    spare_stale_since: Option<usize>,
}

impl ForecastRing {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_built(&self) -> bool {
        self.built
    }

    /// Absolute step of the window's first column.
    pub fn window_start(&self) -> usize {
        self.start
    }

    /// The issue step the current window's forecasts were anchored at.
    pub fn anchor(&self) -> usize {
        self.anchor
    }

    /// Resident forecast bytes (the mirrored f32 rows).
    pub fn bytes(&self) -> usize {
        (self.energy.len() + self.spare.len()) * std::mem::size_of::<f32>()
    }

    /// Is there any excess energy anywhere in the window? Exact (integer
    /// counters). While false, spare rows may be stale (see
    /// `spare_stale_since`) — no selection layer reads them then.
    pub fn window_lit(&self) -> bool {
        self.nonzero_total > 0
    }

    /// Re-issue every forecast at anchor `t` and fill the window
    /// [t, t + d_max). O((C + D) · d_max) when the window is lit; a fully
    /// dark window skips the per-client spare fills entirely (they are
    /// caught up at the first lit append). Row fills fan out across
    /// threads at scale (identical bytes either way).
    pub fn rebuild(&mut self, src: &impl FcSource, t: usize, d_max: usize) {
        assert!(d_max >= 1, "d_max must be at least 1");
        self.d_max = d_max;
        self.n_domains = src.n_domains();
        self.n_clients = src.n_clients();
        self.anchor = t;
        self.start = t;
        self.head = 0;
        self.energy.clear();
        self.energy.resize(self.n_domains * 2 * d_max, 0.0);
        // spare rows are fully overwritten below (or marked stale), so
        // only reshape when the geometry changed — no O(C·d_max) zeroing
        let spare_len = self.n_clients * 2 * d_max;
        if self.spare.len() != spare_len {
            self.spare.clear();
            self.spare.resize(spare_len, 0.0);
        }
        self.nonzero.clear();
        self.nonzero.resize(self.n_domains, 0);

        par::par_fill_rows(&mut self.energy, 2 * d_max, MIN_FILL_ROWS, |p, row| {
            for k in 0..d_max {
                let v = src.energy_at(t, t + k, p) as f32;
                row[k] = v;
                row[k + d_max] = v;
            }
        });
        for (p, cnt) in self.nonzero.iter_mut().enumerate() {
            *cnt = self.energy[p * 2 * d_max..p * 2 * d_max + d_max]
                .iter()
                .filter(|&&v| v > 0.0)
                .count() as u32;
        }
        self.nonzero_total = self.nonzero.iter().map(|&c| c as u64).sum();
        if self.nonzero_total > 0 {
            self.spare_stale_since = None;
            par::par_fill_rows(&mut self.spare, 2 * d_max, MIN_FILL_ROWS, |i, row| {
                for k in 0..d_max {
                    let v = src.spare_at(t, t + k, i) as f32;
                    row[k] = v;
                    row[k + d_max] = v;
                }
            });
        } else {
            // fully dark at issue time: every spare column is stale until
            // the first lit append catches the whole window up
            self.spare_stale_since = Some(t);
        }
        self.built = true;
    }

    /// Shift the window one slot: evict the column at `window_start`,
    /// append the column at `window_start + d_max` fetched at the SAME
    /// anchor. O(C + D) — one forecast fetch + two writes per row, and an
    /// exact integer patch of the liveness counters. While the window is
    /// fully dark the per-client spare append is skipped too (**O(D)**:
    /// no client row is touched at all); the first lit append refetches
    /// every skipped column before any reader can observe it.
    pub fn advance(&mut self, src: &impl FcSource) {
        assert!(self.built, "advance() before rebuild()");
        let dm = self.d_max;
        let h = self.head;
        let t_new = self.start + dm;
        let anchor = self.anchor;
        for p in 0..self.n_domains {
            let base = p * 2 * dm;
            let evicted = self.energy[base + h];
            let v = src.energy_at(anchor, t_new, p) as f32;
            self.energy[base + h] = v;
            self.energy[base + h + dm] = v;
            if evicted > 0.0 {
                self.nonzero[p] -= 1;
                self.nonzero_total -= 1;
            }
            if v > 0.0 {
                self.nonzero[p] += 1;
                self.nonzero_total += 1;
            }
        }
        self.start += 1;
        self.head = (self.head + 1) % dm;
        if self.nonzero_total > 0 {
            // lit: catch up any columns skipped during a dark stretch
            // (clamped to the window — older skipped columns are gone),
            // then the steady state fills exactly the appended column
            let from = self.spare_stale_since.take().unwrap_or(t_new);
            self.fill_spare_cols(src, from.max(self.start), t_new);
        } else if self.spare_stale_since.is_none() {
            self.spare_stale_since = Some(t_new);
        }
    }

    /// Fetch and mirror-write spare columns for the absolute steps
    /// `[from, to]` (inclusive; must lie within the current window).
    fn fill_spare_cols(&mut self, src: &impl FcSource, from: usize, to: usize) {
        let dm = self.d_max;
        debug_assert!(from >= self.start && to < self.start + dm && from <= to);
        let head = self.head;
        let start = self.start;
        let anchor = self.anchor;
        par::par_fill_rows(&mut self.spare, 2 * dm, MIN_FILL_ROWS, |i, row| {
            for c in from..=to {
                let v = src.spare_at(anchor, c, i) as f32;
                let j = (head + (c - start)) % dm;
                row[j] = v;
                row[j + dm] = v;
            }
        });
    }

    /// Refetch any spare columns skipped during a fully dark stretch so
    /// the whole window is byte-identical to a fresh build. A no-op when
    /// nothing is stale. Selection never needs this (dark columns are
    /// never read); it exists for the equivalence tests and any external
    /// consumer that wants to inspect spare rows of a dark window.
    pub fn refresh_spare(&mut self, src: &impl FcSource) {
        if let Some(from) = self.spare_stale_since.take() {
            let last = self.start + self.d_max - 1;
            self.fill_spare_cols(src, from.max(self.start), last);
        }
    }

    pub fn view(&self) -> FcView<'_> {
        assert!(self.built, "view() before rebuild()");
        FcView {
            n_domains: self.n_domains,
            n_clients: self.n_clients,
            d_max: self.d_max,
            stride: 2 * self.d_max,
            head: self.head,
            phase: self.start - self.anchor,
            energy: &self.energy,
            spare: &self.spare,
            nonzero: &self.nonzero,
        }
    }
}

/// Owned, flat (non-ring) forecast buffers: the fresh-build reference the
/// ring is property-tested against, and the fixture type for tests and
/// benches that historically passed `&[Vec<f64>]` forecast rows.
#[derive(Clone, Debug)]
pub struct FcBuffers {
    d_max: usize,
    n_domains: usize,
    n_clients: usize,
    /// advances since the anchor this window corresponds to (see
    /// [`FcView::phase`]); 0 for anchor-fresh windows built from rows
    phase: usize,
    energy: Vec<f32>,
    spare: Vec<f32>,
    nonzero: Vec<u32>,
}

impl FcBuffers {
    /// Build from per-domain energy rows and per-client spare rows (Wh
    /// and batches per step). Short rows are zero-padded, long rows
    /// truncated to `d_max`. Spare rows must already be clamped to each
    /// client's capacity (see the module invariants).
    pub fn from_rows(energy_fc: &[Vec<f64>], spare_fc: &[Vec<f64>], d_max: usize) -> Self {
        let n_domains = energy_fc.len();
        let n_clients = spare_fc.len();
        let mut energy = vec![0.0f32; n_domains * d_max];
        for (p, src) in energy_fc.iter().enumerate() {
            let row = &mut energy[p * d_max..(p + 1) * d_max];
            for (k, v) in src.iter().take(d_max).enumerate() {
                row[k] = *v as f32;
            }
        }
        let mut spare = vec![0.0f32; n_clients * d_max];
        for (i, src) in spare_fc.iter().enumerate() {
            let row = &mut spare[i * d_max..(i + 1) * d_max];
            for (k, v) in src.iter().take(d_max).enumerate() {
                row[k] = *v as f32;
            }
        }
        let nonzero = (0..n_domains)
            .map(|p| {
                energy[p * d_max..(p + 1) * d_max]
                    .iter()
                    .filter(|&&v| v > 0.0)
                    .count() as u32
            })
            .collect();
        FcBuffers { d_max, n_domains, n_clients, phase: 0, energy, spare, nonzero }
    }

    /// Fresh build of the window [t, t + d_max) with forecasts issued at
    /// `anchor` — the reference a ring advanced `t - anchor` times must
    /// match byte for byte (including the bucket-alignment phase).
    pub fn from_source(src: &impl FcSource, anchor: usize, t: usize, d_max: usize) -> Self {
        assert!(t >= anchor, "window start before its forecast anchor");
        let energy_fc: Vec<Vec<f64>> = (0..src.n_domains())
            .map(|p| (t..t + d_max).map(|k| src.energy_at(anchor, k, p)).collect())
            .collect();
        let spare_fc: Vec<Vec<f64>> = (0..src.n_clients())
            .map(|i| (t..t + d_max).map(|k| src.spare_at(anchor, k, i)).collect())
            .collect();
        let mut out = Self::from_rows(&energy_fc, &spare_fc, d_max);
        out.phase = t - anchor;
        out
    }

    pub fn view(&self) -> FcView<'_> {
        FcView {
            n_domains: self.n_domains,
            n_clients: self.n_clients,
            d_max: self.d_max,
            stride: self.d_max,
            head: 0,
            phase: self.phase,
            energy: &self.energy,
            spare: &self.spare,
            nonzero: &self.nonzero,
        }
    }
}

/// Forecaster-backed [`FcSource`] over raw series: used by the ring
/// property tests, the endtoend bench's ring-vs-fresh divergence gate,
/// and anywhere else a standalone window source is needed. Spare values
/// are clamped to the per-client capacity, matching the engine's source.
pub struct SeriesSource {
    pub energy: Vec<crate::trace::forecast::SeriesForecaster>,
    pub spare: Vec<crate::trace::forecast::SeriesForecaster>,
    pub caps: Vec<f64>,
}

impl FcSource for SeriesSource {
    fn n_domains(&self) -> usize {
        self.energy.len()
    }

    fn n_clients(&self) -> usize {
        self.spare.len()
    }

    fn energy_at(&self, t0: usize, t: usize, p: usize) -> f64 {
        self.energy[p].forecast(t0, t)
    }

    fn spare_at(&self, t0: usize, t: usize, i: usize) -> f64 {
        self.spare[i].forecast(t0, t).clamp(0.0, self.caps[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::forecast::SeriesForecaster;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn random_source(rng: &mut Rng, horizon: usize, realistic: bool) -> SeriesSource {
        let n_domains = rng.range(1, 5);
        let n_clients = rng.range(2, 12);
        let mk = |rng: &mut Rng, base: f64, realistic: bool| {
            // dark stretches: zero out a sine's negative half
            let series: Vec<f64> = (0..horizon)
                .map(|t| (base * ((t as f64 / 17.0).sin())).max(0.0))
                .collect();
            if realistic {
                SeriesForecaster::realistic(series, rng.next_u64(), 60.0)
            } else {
                SeriesForecaster::perfect(series)
            }
        };
        let energy = (0..n_domains)
            .map(|_| {
                let base = rng.range_f64(0.0, 800.0);
                mk(rng, base, realistic)
            })
            .collect();
        let caps: Vec<f64> = (0..n_clients).map(|_| rng.range_f64(1.0, 50.0)).collect();
        let spare = caps
            .iter()
            .map(|&c| {
                let base = rng.range_f64(0.0, 2.0 * c);
                mk(rng, base, realistic)
            })
            .collect();
        SeriesSource { energy, spare, caps }
    }

    fn assert_views_identical(a: FcView<'_>, b: FcView<'_>, what: &str) {
        assert_eq!(a.d_max(), b.d_max(), "{what}: d_max");
        assert_eq!(a.phase(), b.phase(), "{what}: phase");
        assert_eq!(a.n_domains(), b.n_domains(), "{what}: n_domains");
        assert_eq!(a.n_clients(), b.n_clients(), "{what}: n_clients");
        for p in 0..a.n_domains() {
            // f32 bit equality (values are never NaN here)
            assert_eq!(a.energy_row(p), b.energy_row(p), "{what}: energy row {p}");
            assert_eq!(a.domain_alive(p), b.domain_alive(p), "{what}: alive {p}");
        }
        for i in 0..a.n_clients() {
            assert_eq!(a.spare_row(i), b.spare_row(i), "{what}: spare row {i}");
        }
    }

    #[test]
    fn advance_is_byte_identical_to_fresh_build() {
        // the tentpole invariant: N consecutive advances == fresh build at
        // the same anchor, for perfect AND error-bearing forecasters,
        // including dark stretches — exact to the bit
        forall(20, |rng| {
            let d_max = rng.range(1, 40);
            let steps = rng.range(1, 50);
            let horizon = d_max + steps + 5;
            let realistic = rng.bool(0.5);
            let src = random_source(rng, horizon, realistic);
            let anchor = rng.range(0, 4);
            let mut ring = ForecastRing::new();
            ring.rebuild(&src, anchor, d_max);
            // fully dark windows legitimately defer their spare fills;
            // refresh_spare makes them observable for the byte comparison
            ring.refresh_spare(&src);
            let fresh0 = FcBuffers::from_source(&src, anchor, anchor, d_max);
            assert_views_identical(ring.view(), fresh0.view(), "rebuild");
            for k in 1..=steps {
                ring.advance(&src);
                ring.refresh_spare(&src);
                assert_eq!(ring.window_start(), anchor + k);
                assert_eq!(ring.anchor(), anchor);
                let fresh = FcBuffers::from_source(&src, anchor, anchor + k, d_max);
                assert_views_identical(ring.view(), fresh.view(), "advance");
            }
        });
    }

    #[test]
    fn dark_stretch_spare_catches_up_without_manual_refresh() {
        // 15 fully dark steps (spare appends deferred), then power
        // returns: the first lit append must refetch every still-in-window
        // skipped column, so the view equals a fresh build with NO manual
        // refresh_spare call — the auto catch-up the selection path relies
        // on. A second dark stretch exercises re-entry into laziness.
        let energy = [vec![6.0; 4], vec![0.0; 15], vec![3.0; 20], vec![0.0; 30]]
            .concat();
        let horizon = energy.len();
        let caps = vec![5.0, 9.0, 2.5];
        let spare: Vec<SeriesForecaster> = caps
            .iter()
            .enumerate()
            .map(|(i, &cap)| {
                let series: Vec<f64> =
                    (0..horizon).map(|t| cap * (0.3 + 0.7 * ((t + i) % 3) as f64 / 2.0)).collect();
                SeriesForecaster::realistic(series, 5 + i as u64, 60.0)
            })
            .collect();
        let src = SeriesSource {
            energy: vec![SeriesForecaster::perfect(energy)],
            spare,
            caps,
        };
        let d_max = 6;
        let mut ring = ForecastRing::new();
        ring.rebuild(&src, 0, d_max);
        let mut saw_dark = false;
        for k in 1..=horizon - d_max - 1 {
            ring.advance(&src);
            if !ring.window_lit() {
                saw_dark = true;
                continue; // stale spare allowed (and unreadable) here
            }
            let fresh = FcBuffers::from_source(&src, 0, k, d_max);
            assert_views_identical(ring.view(), fresh.view(), "lit window");
        }
        assert!(saw_dark, "fixture never went fully dark");
    }

    #[test]
    fn rebuild_resets_anchor_and_window() {
        let mut rng = Rng::new(3);
        let src = random_source(&mut rng, 200, true);
        let mut ring = ForecastRing::new();
        ring.rebuild(&src, 0, 20);
        for _ in 0..7 {
            ring.advance(&src);
        }
        assert_eq!(ring.anchor(), 0);
        ring.rebuild(&src, 31, 20);
        ring.refresh_spare(&src);
        assert_eq!(ring.anchor(), 31);
        assert_eq!(ring.window_start(), 31);
        let fresh = FcBuffers::from_source(&src, 31, 31, 20);
        assert_views_identical(ring.view(), fresh.view(), "re-anchor");
    }

    #[test]
    fn nonzero_counters_track_dark_transitions() {
        // hand-built series with a hard dark edge; counters must track the
        // window crossing it exactly
        let series = [vec![5.0; 10], vec![0.0; 30]].concat();
        let src = SeriesSource {
            energy: vec![SeriesForecaster::perfect(series)],
            spare: vec![SeriesForecaster::perfect(vec![4.0; 40])],
            caps: vec![4.0],
        };
        let mut ring = ForecastRing::new();
        ring.rebuild(&src, 0, 8);
        assert!(ring.view().domain_alive(0));
        for k in 1..=20 {
            ring.advance(&src);
            let window_has_power = ring.window_start() < 10;
            assert_eq!(
                ring.view().domain_alive(0),
                window_has_power,
                "window start {k}"
            );
        }
    }

    #[test]
    fn from_rows_pads_and_truncates() {
        let b = FcBuffers::from_rows(
            &[vec![1.0, 2.0], vec![3.0; 8]],
            &[vec![0.5; 3]],
            4,
        );
        let v = b.view();
        assert_eq!(v.energy_row(0), &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(v.energy_row(1), &[3.0; 4]);
        assert_eq!(v.spare_row(0), &[0.5, 0.5, 0.5, 0.0]);
        assert!(v.domain_alive(0) && v.domain_alive(1));
        let dark = FcBuffers::from_rows(&[vec![0.0; 4]], &[], 4);
        assert!(!dark.view().domain_alive(0));
    }

    #[test]
    fn mirrored_window_is_contiguous_at_every_head() {
        // d_max steps of advance walk head through every position incl.
        // the wrap; row slicing must never touch stale mirror halves
        let mut rng = Rng::new(9);
        let src = random_source(&mut rng, 100, true);
        let d_max = 7;
        let mut ring = ForecastRing::new();
        ring.rebuild(&src, 0, d_max);
        for k in 1..=2 * d_max + 1 {
            ring.advance(&src);
            ring.refresh_spare(&src);
            let fresh = FcBuffers::from_source(&src, 0, k, d_max);
            assert_views_identical(ring.view(), fresh.view(), "wrap");
        }
    }
}
