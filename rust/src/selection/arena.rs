//! The flat forecast arena behind FedZero's binary search (Fig-8 path).
//!
//! Algorithm 1 probes O(log d_max) candidate round durations `d`, and the
//! historical pipeline re-materialised every forecast per probe: energy
//! windows were `w[..d].to_vec()`'d per domain, spare windows rebuilt per
//! eligible client, and the line-6/line-11 pre-filters re-scanned O(C·d)
//! forecast entries — twice, because `build_instance` and `eligible_ids`
//! maintained the same filter independently.
//!
//! [`SelArena`] replaces all of that with one flat, prefix-summed copy of
//! the forecasts built per `select()` call:
//!
//! * `energy` / `spare` — row-major [domains × d_max] and
//!   [clients × d_max] matrices; a probe at duration `d` borrows
//!   `row[..d]` slice views, so narrowing the window is pointer
//!   arithmetic, not a copy (monotone feasibility means every probe can
//!   share the d_max arena and just narrow its view);
//! * `energy_prefix` — running sums per domain, making the paper's
//!   line-6 "domain has excess energy within d" filter O(1) per probe;
//! * `d_reach` — the smallest feasible duration per client under the
//!   line-11 standalone filter (monotone in d), folding in the blocklist
//!   and σ_c > 0 checks, making per-probe client eligibility a single
//!   integer compare.
//!
//! The O(C·d_max) construction passes fan out across threads at scale
//! (`util::par`; identical results to the serial fill). One
//! [`ProbeScratch`] is reused across all probes of a search, so the
//! steady-state per-probe cost is filling three flat `Vec`s of POD
//! entries — no per-probe forecast allocation at all.

use super::SelectionContext;
use crate::solver::mip::{ClientView, InstanceView};
use crate::util::par;

/// Row counts below which arena construction stays single-threaded.
const PAR_MIN_ROWS: usize = 2048;

/// Flat per-`select()` forecast arena; see the module docs.
pub struct SelArena {
    /// clients required per round (ctx.n)
    pub n: usize,
    pub d_max: usize,
    n_clients: usize,
    n_domains: usize,
    /// [n_domains × d_max] excess-energy forecast, Wh/step
    energy: Vec<f64>,
    /// prefix[p·(d_max+1) + d] = Σ energy[p][0..d] (left fold, same float
    /// semantics as the historical `w[..d].iter().sum()`)
    energy_prefix: Vec<f64>,
    /// [n_clients × d_max] spare capacity, batches/step, pre-clamped to
    /// the client's total capacity
    spare: Vec<f64>,
    /// smallest d (1-based) at which client i passes the line-11
    /// reachability filter, with blocklist/σ folded in; usize::MAX = never
    d_reach: Vec<usize>,
    // per-client scalars copied once so probe filling never touches the
    // original context
    domain: Vec<usize>,
    sigma: Vec<f64>,
    delta: Vec<f64>,
    m_min: Vec<f64>,
    m_max: Vec<f64>,
}

/// Reusable per-probe buffers of borrowed views into a [`SelArena`].
/// Cleared and refilled by [`SelArena::fill_probe`]; holds POD entries
/// only, so refills never allocate once capacity has grown.
#[derive(Default)]
pub struct ProbeScratch<'a> {
    n: usize,
    clients: Vec<ClientView<'a>>,
    energy: Vec<&'a [f64]>,
    /// original context client ids, parallel to `clients` — the id map
    /// that used to live in the duplicated `eligible_ids` filter
    pub ids: Vec<usize>,
}

impl<'a> ProbeScratch<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    /// The solver view of the last filled probe.
    pub fn instance(&self) -> InstanceView<'_> {
        InstanceView { n: self.n, clients: &self.clients, energy: &self.energy }
    }
}

impl SelArena {
    /// The d_max eligibility count straight off the context, WITHOUT
    /// materialising the arena — the dark-period early exit. Applies the
    /// same line-6/8/11 filters as [`Self::fill_probe`]; `reachable_min`
    /// early-breaks and dead domains short-circuit it entirely, so idle
    /// (night) steps cost one forecast scan and zero allocations beyond
    /// the domain bitmap.
    ///
    /// KEEP IN SYNC with the filter in [`Self::build`]/[`Self::eligible`]:
    /// any new eligibility condition must land in both places, or select()
    /// will wait on rounds the arena considers feasible. Agreement is
    /// property-tested in `tests::quick_count_agrees_with_arena`.
    pub fn quick_eligible_count(ctx: &SelectionContext) -> usize {
        let d = ctx.d_max;
        let domain_alive: Vec<bool> = ctx
            .energy_fc
            .iter()
            .map(|w| w[..d.min(w.len())].iter().sum::<f64>() > 1e-9)
            .collect();
        (0..ctx.clients.len())
            .filter(|&i| {
                !ctx.states[i].blocked
                    && ctx.states[i].sigma > 0.0
                    && domain_alive[ctx.clients[i].domain]
                    && ctx.reachable_min(i, d)
            })
            .count()
    }

    /// Copy the context's forecasts into flat storage and precompute the
    /// prefix sums and per-client reachability curve.
    pub fn build(ctx: &SelectionContext) -> SelArena {
        let n_clients = ctx.clients.len();
        let n_domains = ctx.energy_fc.len();
        let d_max = ctx.d_max;

        // per-client scalars (also used by the parallel passes below, so
        // the closures only capture plain slices)
        let mut domain = Vec::with_capacity(n_clients);
        let mut sigma = Vec::with_capacity(n_clients);
        let mut delta = Vec::with_capacity(n_clients);
        let mut m_min = Vec::with_capacity(n_clients);
        let mut m_max = Vec::with_capacity(n_clients);
        let mut capacity = Vec::with_capacity(n_clients);
        let mut live = Vec::with_capacity(n_clients); // !blocked && σ > 0
        for (i, c) in ctx.clients.iter().enumerate() {
            domain.push(c.domain);
            sigma.push(ctx.states[i].sigma);
            delta.push(c.delta());
            m_min.push(c.m_min);
            m_max.push(c.m_max);
            capacity.push(c.capacity());
            live.push(!ctx.states[i].blocked && ctx.states[i].sigma > 0.0);
        }

        // the parallel passes below capture plain forecast slices only
        // (not the whole context, whose domain/client structs need not be
        // Sync)
        let energy_fc: &[Vec<f64>] = ctx.energy_fc;
        let spare_fc: &[Vec<f64>] = ctx.spare_fc;

        // energy rows (short forecast rows are zero-padded)
        let mut energy = vec![0.0f64; n_domains * d_max];
        if d_max > 0 {
            for (p, row) in energy.chunks_mut(d_max).enumerate() {
                let src = &energy_fc[p];
                let take = src.len().min(d_max);
                row[..take].copy_from_slice(&src[..take]);
            }
        }
        let mut energy_prefix = vec![0.0f64; n_domains * (d_max + 1)];
        par::par_fill_rows(&mut energy_prefix, d_max + 1, PAR_MIN_ROWS, |p, row| {
            let src = &energy[p * d_max..(p + 1) * d_max];
            let mut acc = 0.0;
            row[0] = 0.0;
            for (t, &e) in src.iter().enumerate() {
                acc += e;
                row[t + 1] = acc;
            }
        });

        // spare rows, clamped to capacity (the historical per-probe
        // `spare_fc[i][t].min(c.capacity())`)
        let mut spare = vec![0.0f64; n_clients * d_max];
        par::par_fill_rows(&mut spare, d_max, PAR_MIN_ROWS, |i, row| {
            let src = &spare_fc[i];
            let cap = capacity[i];
            let take = src.len().min(d_max);
            for t in 0..take {
                row[t] = src[t].min(cap);
            }
        });

        // line-11 reachability: smallest d where the cumulative standalone
        // batch curve crosses m_min (min(spare, r/δ) is evaluated exactly
        // as the historical `reachable_min`: min is exact in floats, so
        // clamping spare first is equivalent)
        let mut d_reach = vec![usize::MAX; n_clients];
        par::par_fill_rows(&mut d_reach, 1, PAR_MIN_ROWS, |i, out| {
            if !live[i] {
                return; // stays usize::MAX
            }
            let erow = &energy[domain[i] * d_max..(domain[i] + 1) * d_max];
            let srow = &spare[i * d_max..(i + 1) * d_max];
            let dl = delta[i];
            let need = m_min[i];
            let mut cum = 0.0;
            for t in 0..d_max {
                cum += srow[t].min(erow[t] / dl);
                if cum >= need {
                    out[0] = t + 1;
                    return;
                }
            }
        });

        SelArena {
            n: ctx.n,
            d_max,
            n_clients,
            n_domains,
            energy,
            energy_prefix,
            spare,
            d_reach,
            domain,
            sigma,
            delta,
            m_min,
            m_max,
        }
    }

    /// Σ energy of domain `p` over the first `d` steps (O(1)).
    #[inline]
    fn energy_sum(&self, p: usize, d: usize) -> f64 {
        self.energy_prefix[p * (self.d_max + 1) + d]
    }

    /// Is client `i` eligible at duration `d`? (line-6 + line-8 + line-11
    /// pre-filters, all O(1) per query)
    #[inline]
    fn eligible(&self, i: usize, d: usize) -> bool {
        self.d_reach[i] <= d && self.energy_sum(self.domain[i], d) > 1e-9
    }

    /// Number of eligible clients at duration `d` — the cheap necessary
    /// condition checked before the binary search.
    pub fn eligible_count(&self, d: usize) -> usize {
        (0..self.n_clients).filter(|&i| self.eligible(i, d)).count()
    }

    /// Fill `scratch` with the probe instance for duration `d`: slice
    /// views into the arena for every eligible client plus the parallel
    /// id map. Returns false when fewer than `n` clients survive the
    /// filters (the probe is infeasible without solving).
    pub fn fill_probe<'a>(&'a self, scratch: &mut ProbeScratch<'a>, d: usize) -> bool {
        assert!(d >= 1 && d <= self.d_max, "probe duration {d} out of range");
        scratch.n = self.n;
        scratch.energy.clear();
        for p in 0..self.n_domains {
            scratch.energy.push(&self.energy[p * self.d_max..p * self.d_max + d]);
        }
        scratch.clients.clear();
        scratch.ids.clear();
        for i in 0..self.n_clients {
            if !self.eligible(i, d) {
                continue;
            }
            scratch.clients.push(ClientView {
                domain: self.domain[i],
                sigma: self.sigma[i],
                delta: self.delta[i],
                m_min: self.m_min[i],
                m_max: self.m_max[i],
                spare: &self.spare[i * self.d_max..i * self.d_max + d],
            });
            scratch.ids.push(i);
        }
        scratch.clients.len() >= self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
    use crate::energy::PowerDomain;
    use crate::selection::ClientRoundState;
    use crate::trace::forecast::SeriesForecaster;

    fn scenario(
        n_clients: usize,
        n_domains: usize,
        power_w: f64,
        d_max: usize,
    ) -> (
        Vec<ClientInfo>,
        Vec<ClientRoundState>,
        Vec<PowerDomain>,
        Vec<Vec<f64>>,
        Vec<Vec<f64>>,
        Vec<f64>,
    ) {
        let clients: Vec<ClientInfo> = (0..n_clients)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::ALL[i % 3],
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                ClientInfo::new(i, i % n_domains, p, (0..50).collect(), 10)
            })
            .collect();
        let states = vec![ClientRoundState::default(); n_clients];
        let domains: Vec<PowerDomain> = (0..n_domains)
            .map(|i| {
                let series = vec![power_w; d_max * 2];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let energy_fc: Vec<Vec<f64>> =
            domains.iter().map(|d| d.forecast_window_wh(0, d_max)).collect();
        let spare_fc: Vec<Vec<f64>> = clients
            .iter()
            .map(|c| vec![c.capacity(); d_max])
            .collect();
        let spare_now: Vec<f64> = clients.iter().map(|c| c.capacity()).collect();
        (clients, states, domains, energy_fc, spare_fc, spare_now)
    }

    #[test]
    fn probe_matches_manual_filter() {
        let (clients, mut states, domains, efc, sfc, snow) =
            scenario(12, 3, 800.0, 30);
        states[2].blocked = true;
        states[2].sigma = 0.0;
        states[7].sigma = 0.0;
        let ctx = SelectionContext {
            now: 0,
            n: 3,
            d_max: 30,
            clients: &clients,
            states: &states,
            domains: &domains,
            energy_fc: &efc,
            spare_fc: &sfc,
            spare_now: &snow,
        };
        let arena = SelArena::build(&ctx);
        let mut scratch = ProbeScratch::new();
        for d in [1usize, 7, 30] {
            let ok = arena.fill_probe(&mut scratch, d);
            // manual filter via the context's own reachable_min
            let expect: Vec<usize> = (0..clients.len())
                .filter(|&i| {
                    !states[i].blocked
                        && states[i].sigma > 0.0
                        && efc[clients[i].domain][..d].iter().sum::<f64>() > 1e-9
                        && ctx.reachable_min(i, d)
                })
                .collect();
            assert_eq!(scratch.ids, expect, "d={d}");
            assert_eq!(ok, expect.len() >= 3, "d={d}");
            let inst = scratch.instance();
            assert_eq!(inst.clients.len(), expect.len());
            for (k, &i) in scratch.ids.iter().enumerate() {
                assert_eq!(inst.clients[k].domain, clients[i].domain);
                assert_eq!(inst.clients[k].spare.len(), d);
            }
            for row in inst.energy {
                assert_eq!(row.len(), d);
            }
        }
    }

    #[test]
    fn dead_domains_remove_their_clients() {
        let (clients, states, mut domains, mut efc, sfc, snow) =
            scenario(9, 3, 800.0, 20);
        // kill domain 1's forecast
        efc[1] = vec![0.0; 20];
        domains[1] = PowerDomain::new(
            1,
            "d",
            800.0,
            vec![0.0; 40],
            SeriesForecaster::perfect(vec![0.0; 40]),
            1.0,
        );
        let ctx = SelectionContext {
            now: 0,
            n: 2,
            d_max: 20,
            clients: &clients,
            states: &states,
            domains: &domains,
            energy_fc: &efc,
            spare_fc: &sfc,
            spare_now: &snow,
        };
        let arena = SelArena::build(&ctx);
        let mut scratch = ProbeScratch::new();
        assert!(arena.fill_probe(&mut scratch, 20));
        for &i in &scratch.ids {
            assert_ne!(clients[i].domain, 1, "client {i} from a dead domain");
        }
        assert_eq!(arena.eligible_count(20), scratch.ids.len());
        // the allocation-free precheck must agree with the arena filter
        assert_eq!(SelArena::quick_eligible_count(&ctx), scratch.ids.len());
    }

    #[test]
    fn quick_count_agrees_with_arena() {
        // randomized blocked/σ patterns and power levels: the
        // allocation-free precheck and the arena filter must agree at
        // d_max in every scenario (guards the duplicated-filter drift
        // this module's docs warn about)
        crate::util::prop::forall(25, |rng| {
            let n_clients = rng.range(3, 20);
            let n_domains = rng.range(1, 5);
            let d_max = rng.range(5, 40);
            let power = rng.range_f64(0.0, 200.0);
            let (clients, mut states, domains, efc, sfc, snow) =
                scenario(n_clients, n_domains, power, d_max);
            for s in states.iter_mut() {
                s.blocked = rng.bool(0.3);
                s.sigma = if s.blocked { 0.0 } else { rng.range_f64(0.0, 5.0) };
            }
            let ctx = SelectionContext {
                now: 0,
                n: 1,
                d_max,
                clients: &clients,
                states: &states,
                domains: &domains,
                energy_fc: &efc,
                spare_fc: &sfc,
                spare_now: &snow,
            };
            let arena = SelArena::build(&ctx);
            assert_eq!(
                SelArena::quick_eligible_count(&ctx),
                arena.eligible_count(d_max),
                "precheck disagrees with arena filter"
            );
        });
    }

    #[test]
    fn eligibility_is_monotone_in_d() {
        let (clients, states, domains, efc, sfc, snow) = scenario(10, 2, 40.0, 25);
        let ctx = SelectionContext {
            now: 0,
            n: 2,
            d_max: 25,
            clients: &clients,
            states: &states,
            domains: &domains,
            energy_fc: &efc,
            spare_fc: &sfc,
            spare_now: &snow,
        };
        let arena = SelArena::build(&ctx);
        let mut prev = 0;
        for d in 1..=25 {
            let count = arena.eligible_count(d);
            assert!(count >= prev, "eligibility shrank at d={d}");
            prev = count;
        }
    }
}
