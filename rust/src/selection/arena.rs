//! The forecast arena behind FedZero's binary search (Fig-8 path).
//!
//! Algorithm 1 probes O(log d_max) candidate round durations `d`; every
//! probe needs, per eligible client, a spare-capacity row of length `d`
//! and, per domain, an energy row of length `d`, plus the paper's
//! line-6/line-11 pre-filters. The historical pipeline re-materialised
//! those forecasts per probe; the previous arena copied them into flat
//! per-`select()` f64 storage.
//!
//! [`SelArena`] **borrows** its forecast storage from the
//! [`super::ring::FcView`] handed in through the [`SelectionContext`] —
//! the persistent f32 ring-arena the simulation advances incrementally
//! (see `selection::ring`) — and, when the context carries the
//! persistent [`super::incr::IncrSelState`], it also borrows every
//! filter structure instead of recomputing it:
//!
//! * **effective reach** — the smallest duration at which a client
//!   passes ALL pre-filters (line 6 domain energy, line 8 blocklist/σ,
//!   line 11 standalone reachability), one integer per client. With the
//!   incremental state attached this is a borrowed lookup (the state
//!   patches it on ring advance — O(C·d_max) per select → nothing);
//!   without it, it is derived freshly via the canonical bucketed walk
//!   ([`super::incr::reach_walk`]) — bit-identical by construction.
//!   For `m_min > 0` the line-6 energy condition is implied by the
//!   reach crossing (a positive term needs a positive energy column);
//!   `m_min <= 0` clients fold in the domain's first lit column.
//! * **cumulative eligibility histogram** — `cum_elig[d]` = #clients
//!   with reach ≤ d, built once per `select()` in O(C + d_max) integer
//!   work, making `eligible_count(d)` **O(1) per probe** and letting
//!   `fill_probe` reject infeasible probes without scanning a single
//!   client (the historical filter scanned all C clients per probe).
//! * **per-client scalars** (σ, δ, m_min, m_max, domain, liveness) —
//!   with the incremental state attached these are BORROWED from its
//!   rebuild-time snapshot (σ is the only per-round mutable in the set,
//!   and the engine rebuilds the state right after every round-end σ
//!   refresh), so a build performs no O(C) copies at all; without it,
//!   one O(C) copy pass — the historical cost (ROADMAP "incremental
//!   arena scalars").
//!
//! Probes then borrow `row[..d]` slice views straight out of the ring
//! (monotone feasibility means every probe shares the d_max window and
//! just narrows its view); one [`ProbeScratch`] is reused across all
//! probes of a search, so the steady-state per-probe cost is filling
//! three flat `Vec`s of POD entries — no forecast copy anywhere in the
//! pipeline. Construction passes fan out across threads at scale
//! (`util::par`; identical results to the serial fill).
//!
//! Forecast values are f32 end to end (ring → arena → solver views) and
//! widened to f64 wherever arithmetic happens — every layer reads the
//! same quantised bits, which together with the single canonical
//! accumulation order (`selection::incr` module docs) makes the
//! ring-advance, fresh-build, incremental and quick-gate paths agree
//! exactly (property-tested below, in `selection::incr`, and in
//! `tests/integration_ring.rs`).

use super::incr::{self, IncrSelState, ScalarTable};
use super::SelectionContext;
use crate::solver::mip::{ClientView, InstanceView};
use crate::util::par;
use crate::util::par::thresholds::MIN_FILL_ROWS;

/// Where the per-client effective reach comes from: borrowed from the
/// persistent incremental state, or derived freshly per `select()`.
enum EffSource<'a> {
    Incr(&'a IncrSelState),
    Fresh(Vec<usize>),
}

/// Owned per-client scalars for the incr-less path (tests, baselines
/// without the persistent state attached).
struct OwnedScalars {
    domain: Vec<usize>,
    sigma: Vec<f64>,
    delta: Vec<f64>,
    m_min: Vec<f64>,
    m_max: Vec<f64>,
    live: Vec<bool>,
}

/// Where the per-client scalars come from (ROADMAP "incremental arena
/// scalars"): with the persistent [`IncrSelState`] attached, the arena
/// BORROWS its scalar snapshot — σ is the only per-round mutable in the
/// set and the engine re-captures it at every round end — so a build
/// performs no O(C) scalar copies at all; without it, one O(C) copy
/// pass, exactly the historical cost.
enum Scalars<'a> {
    Incr(ScalarTable<'a>),
    Fresh(OwnedScalars),
}

impl<'a> Scalars<'a> {
    #[inline]
    fn table(&self) -> ScalarTable<'_> {
        match self {
            Scalars::Incr(t) => *t,
            Scalars::Fresh(o) => ScalarTable {
                domain: &o.domain,
                sigma: &o.sigma,
                delta: &o.delta,
                m_min: &o.m_min,
                m_max: &o.m_max,
                live: &o.live,
            },
        }
    }
}

/// Per-`select()` arena: borrowed forecast rows plus the (borrowed or
/// freshly derived) filter structures; see the module docs.
pub struct SelArena<'a> {
    /// clients required per round (ctx.n)
    pub n: usize,
    pub d_max: usize,
    n_clients: usize,
    n_domains: usize,
    /// borrowed forecast window (ring or fresh buffers)
    fc: super::ring::FcView<'a>,
    /// per-client effective reach (see module docs)
    eff: EffSource<'a>,
    /// cum_elig[d] = #clients with effective reach ≤ d (cum_elig[0] = 0)
    cum_elig: Vec<u32>,
    /// per-client scalars — borrowed from the incremental state or
    /// copied once (see [`Scalars`])
    scalars: Scalars<'a>,
}

/// Reusable per-probe buffers of borrowed views into a [`SelArena`]'s
/// forecast window. Cleared and refilled by [`SelArena::fill_probe`];
/// holds POD entries only, so refills never allocate once capacity has
/// grown.
#[derive(Default)]
pub struct ProbeScratch<'a> {
    n: usize,
    clients: Vec<ClientView<'a>>,
    energy: Vec<&'a [f32]>,
    /// original context client ids, parallel to `clients` — the id map
    /// that used to live in the duplicated `eligible_ids` filter
    pub ids: Vec<usize>,
}

impl<'a> ProbeScratch<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    /// The solver view of the last filled probe.
    pub fn instance(&self) -> InstanceView<'_> {
        InstanceView { n: self.n, clients: &self.clients, energy: &self.energy }
    }
}

impl<'a> SelArena<'a> {
    /// The d_max eligibility count straight off the context, WITHOUT
    /// building the arena — the dark-period early exit. With the
    /// incremental state attached this is a pure O(D) per-domain counter
    /// sum (a fully dark idle step touches no client at all); the
    /// fallback applies the same line-6/8/11 filters client by client —
    /// the ring's O(1) liveness counters short-circuit dead domains and
    /// the canonical walk gates on lit columns, so idle (night) steps
    /// cost one domain-counter check per client and zero allocations.
    ///
    /// KEEP IN SYNC with the filter in [`Self::build`]/[`Self::eligible`]
    /// (and `IncrSelState::quick_eligible_count`): any new eligibility
    /// condition must land in all places, or select() will wait on
    /// rounds the arena considers feasible. Agreement is property-tested
    /// in `tests::quick_count_agrees_with_arena` and `selection::incr`.
    pub fn quick_eligible_count(ctx: &SelectionContext) -> usize {
        if let Some(state) = ctx.incr {
            debug_assert_eq!(state.phase(), ctx.fc.phase(), "stale incr state");
            debug_assert_eq!(state.n_clients(), ctx.clients.len());
            return state.quick_eligible_count();
        }
        let d = ctx.d_max;
        (0..ctx.clients.len())
            .filter(|&i| {
                !ctx.states[i].blocked
                    && ctx.states[i].sigma > 0.0
                    && ctx.fc.domain_alive(ctx.clients[i].domain)
                    && ctx.reachable_min(i, d)
            })
            .count()
    }

    /// Assemble the arena over the context's borrowed forecast window:
    /// borrow the persistent reach structures AND the per-client scalar
    /// table when `ctx.incr` is attached (O(C) integer work, zero O(C)
    /// copies), or derive both freshly — one O(C) scalar pass plus the
    /// canonical walks (O(C·d_max)) — bit-identical either way.
    pub fn build(ctx: &SelectionContext<'a>) -> SelArena<'a> {
        let n_clients = ctx.clients.len();
        let n_domains = ctx.fc.n_domains();
        let d_max = ctx.d_max;
        let fc = ctx.fc;
        debug_assert_eq!(fc.d_max(), d_max, "context window shorter than d_max");

        let (eff, scalars) = match ctx.incr {
            Some(state) => {
                debug_assert_eq!(state.phase(), fc.phase(), "stale incr state");
                debug_assert_eq!(state.n_clients(), n_clients);
                debug_assert_eq!(state.d_max(), d_max);
                (EffSource::Incr(state), Scalars::Incr(state.scalar_table()))
            }
            None => {
                // one O(C) scalar pass (the historical per-select cost)…
                let mut owned = OwnedScalars {
                    domain: Vec::with_capacity(n_clients),
                    sigma: Vec::with_capacity(n_clients),
                    delta: Vec::with_capacity(n_clients),
                    m_min: Vec::with_capacity(n_clients),
                    m_max: Vec::with_capacity(n_clients),
                    live: Vec::with_capacity(n_clients),
                };
                for (i, c) in ctx.clients.iter().enumerate() {
                    owned.domain.push(c.domain);
                    owned.sigma.push(ctx.states[i].sigma);
                    owned.delta.push(c.delta());
                    owned.m_min.push(c.m_min);
                    owned.m_max.push(c.m_max);
                    owned.live.push(!ctx.states[i].blocked && ctx.states[i].sigma > 0.0);
                }
                // …then the fresh reach derivation: the canonical
                // bucketed walk (see selection::incr) per live client,
                // plus each domain's first lit column for the
                // m_min <= 0 shortcut
                let bucket = incr::bucket_width(d_max);
                let phase = fc.phase();
                let d_first: Vec<usize> = (0..n_domains)
                    .map(|p| {
                        fc.energy_row(p)
                            .iter()
                            .position(|&e| e > 0.0)
                            .map(|t| t + 1)
                            .unwrap_or(usize::MAX)
                    })
                    .collect();
                let mut eff = vec![usize::MAX; n_clients];
                {
                    let domain = &owned.domain;
                    let delta = &owned.delta;
                    let m_min = &owned.m_min;
                    let live = &owned.live;
                    let d_first = &d_first;
                    par::par_fill_rows(&mut eff, 1, MIN_FILL_ROWS, |i, out| {
                        if !live[i] {
                            return; // stays usize::MAX
                        }
                        if m_min[i] > 0.0 {
                            out[0] = incr::reach_fresh(
                                fc.spare_row(i),
                                fc.energy_row(domain[i]),
                                delta[i],
                                m_min[i],
                                phase,
                                bucket,
                            );
                        } else {
                            out[0] = d_first[domain[i]];
                        }
                    });
                }
                (EffSource::Fresh(eff), Scalars::Fresh(owned))
            }
        };

        // cumulative eligibility histogram: O(C + d_max) integer work,
        // then every eligible_count(d) probe is O(1)
        let mut cum_elig = vec![0u32; d_max + 1];
        for i in 0..n_clients {
            let e = match &eff {
                EffSource::Incr(state) => state.eff_rel(i),
                EffSource::Fresh(v) => v[i],
            };
            if e <= d_max {
                cum_elig[e] += 1;
            }
        }
        for d in 1..=d_max {
            cum_elig[d] += cum_elig[d - 1];
        }

        SelArena {
            n: ctx.n,
            d_max,
            n_clients,
            n_domains,
            fc,
            eff,
            cum_elig,
            scalars,
        }
    }

    /// The effective reach of client `i`: smallest duration at which it
    /// passes every pre-filter; usize::MAX = never (see module docs).
    #[inline]
    pub fn eff_reach(&self, i: usize) -> usize {
        match &self.eff {
            EffSource::Incr(state) => state.eff_rel(i),
            EffSource::Fresh(v) => v[i],
        }
    }

    /// Is client `i` eligible at duration `d`? (line-6 + line-8 + line-11
    /// pre-filters, one integer compare.)
    #[inline]
    fn eligible(&self, i: usize, d: usize) -> bool {
        self.eff_reach(i) <= d
    }

    /// Number of eligible clients at duration `d` — the cheap necessary
    /// condition checked before each probe. O(1): a histogram lookup.
    pub fn eligible_count(&self, d: usize) -> usize {
        assert!(d >= 1 && d <= self.d_max);
        self.cum_elig[d] as usize
    }

    /// Fill `scratch` with the probe instance for duration `d`: slice
    /// views into the borrowed forecast window for every eligible client
    /// plus the parallel id map. Returns false when fewer than `n`
    /// clients survive the filters (the probe is infeasible without
    /// solving) — decided O(1) from the histogram, in which case the
    /// scratch is NOT filled and no client is scanned.
    pub fn fill_probe(&self, scratch: &mut ProbeScratch<'a>, d: usize) -> bool {
        assert!(d >= 1 && d <= self.d_max, "probe duration {d} out of range");
        if (self.cum_elig[d] as usize) < self.n {
            return false;
        }
        scratch.n = self.n;
        scratch.energy.clear();
        for p in 0..self.n_domains {
            scratch.energy.push(&self.fc.energy_row(p)[..d]);
        }
        scratch.clients.clear();
        scratch.ids.clear();
        let t = self.scalars.table();
        for i in 0..self.n_clients {
            if !self.eligible(i, d) {
                continue;
            }
            scratch.clients.push(ClientView {
                domain: t.domain[i],
                sigma: t.sigma[i],
                delta: t.delta[i],
                m_min: t.m_min[i],
                m_max: t.m_max[i],
                spare: &self.fc.spare_row(i)[..d],
            });
            scratch.ids.push(i);
        }
        debug_assert_eq!(scratch.ids.len(), self.cum_elig[d] as usize);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
    use crate::energy::PowerDomain;
    use crate::selection::ring::FcBuffers;
    use crate::selection::ClientRoundState;
    use crate::trace::forecast::SeriesForecaster;

    fn scenario(
        n_clients: usize,
        n_domains: usize,
        power_w: f64,
        d_max: usize,
    ) -> (
        Vec<ClientInfo>,
        Vec<ClientRoundState>,
        Vec<PowerDomain>,
        Vec<Vec<f64>>,
        Vec<Vec<f64>>,
        Vec<f64>,
    ) {
        let clients: Vec<ClientInfo> = (0..n_clients)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::ALL[i % 3],
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                ClientInfo::new(i, i % n_domains, p, (0..50).collect(), 10)
            })
            .collect();
        let states = vec![ClientRoundState::default(); n_clients];
        let domains: Vec<PowerDomain> = (0..n_domains)
            .map(|i| {
                let series = vec![power_w; d_max * 2];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let energy_fc: Vec<Vec<f64>> =
            domains.iter().map(|d| d.forecast_window_wh(0, d_max)).collect();
        let spare_fc: Vec<Vec<f64>> = clients
            .iter()
            .map(|c| vec![c.capacity(); d_max])
            .collect();
        let spare_now: Vec<f64> = clients.iter().map(|c| c.capacity()).collect();
        (clients, states, domains, energy_fc, spare_fc, spare_now)
    }

    #[test]
    fn probe_matches_manual_filter() {
        let (clients, mut states, domains, efc, sfc, snow) =
            scenario(12, 3, 800.0, 30);
        states[2].blocked = true;
        states[2].sigma = 0.0;
        states[7].sigma = 0.0;
        let fc = FcBuffers::from_rows(&efc, &sfc, 30);
        let ctx = SelectionContext {
            now: 0,
            n: 3,
            d_max: 30,
            clients: &clients,
            states: &states,
            domains: &domains,
            fc: fc.view(),
            incr: None,
            spare_now: &snow,
        };
        let arena = SelArena::build(&ctx);
        let mut scratch = ProbeScratch::new();
        for d in [1usize, 7, 30] {
            let ok = arena.fill_probe(&mut scratch, d);
            // manual filter via the context's own reachable_min; the
            // domain-energy condition mirrors the arena's folded filter
            let expect: Vec<usize> = (0..clients.len())
                .filter(|&i| {
                    !states[i].blocked
                        && states[i].sigma > 0.0
                        && fc.view().energy_row(clients[i].domain)[..d]
                            .iter()
                            .fold(0.0f64, |a, &e| a + e as f64)
                            > 0.0
                        && ctx.reachable_min(i, d)
                })
                .collect();
            assert_eq!(ok, expect.len() >= 3, "d={d}");
            assert_eq!(arena.eligible_count(d), expect.len(), "d={d}");
            if !ok {
                // infeasible probes are rejected O(1) off the histogram
                // WITHOUT filling the scratch
                continue;
            }
            assert_eq!(scratch.ids, expect, "d={d}");
            let inst = scratch.instance();
            assert_eq!(inst.clients.len(), expect.len());
            for (k, &i) in scratch.ids.iter().enumerate() {
                assert_eq!(inst.clients[k].domain, clients[i].domain);
                assert_eq!(inst.clients[k].spare.len(), d);
            }
            for row in inst.energy {
                assert_eq!(row.len(), d);
            }
        }
    }

    #[test]
    fn dead_domains_remove_their_clients() {
        let (clients, states, mut domains, mut efc, sfc, snow) =
            scenario(9, 3, 800.0, 20);
        // kill domain 1's forecast
        efc[1] = vec![0.0; 20];
        domains[1] = PowerDomain::new(
            1,
            "d",
            800.0,
            vec![0.0; 40],
            SeriesForecaster::perfect(vec![0.0; 40]),
            1.0,
        );
        let fc = FcBuffers::from_rows(&efc, &sfc, 20);
        let ctx = SelectionContext {
            now: 0,
            n: 2,
            d_max: 20,
            clients: &clients,
            states: &states,
            domains: &domains,
            fc: fc.view(),
            incr: None,
            spare_now: &snow,
        };
        let arena = SelArena::build(&ctx);
        let mut scratch = ProbeScratch::new();
        assert!(arena.fill_probe(&mut scratch, 20));
        for &i in &scratch.ids {
            assert_ne!(clients[i].domain, 1, "client {i} from a dead domain");
        }
        assert_eq!(arena.eligible_count(20), scratch.ids.len());
        // the allocation-free precheck must agree with the arena filter
        assert_eq!(SelArena::quick_eligible_count(&ctx), scratch.ids.len());
    }

    #[test]
    fn quick_count_agrees_with_arena() {
        // randomized blocked/σ patterns and power levels: the
        // allocation-free precheck and the arena filter must agree at
        // d_max in every scenario (guards the duplicated-filter drift
        // this module's docs warn about)
        crate::util::prop::forall(25, |rng| {
            let n_clients = rng.range(3, 20);
            let n_domains = rng.range(1, 5);
            let d_max = rng.range(5, 40);
            let power = rng.range_f64(0.0, 200.0);
            let (clients, mut states, domains, efc, sfc, snow) =
                scenario(n_clients, n_domains, power, d_max);
            for s in states.iter_mut() {
                s.blocked = rng.bool(0.3);
                s.sigma = if s.blocked { 0.0 } else { rng.range_f64(0.0, 5.0) };
            }
            let fc = FcBuffers::from_rows(&efc, &sfc, d_max);
            let ctx = SelectionContext {
                now: 0,
                n: 1,
                d_max,
                clients: &clients,
                states: &states,
                domains: &domains,
                fc: fc.view(),
                incr: None,
                spare_now: &snow,
            };
            let arena = SelArena::build(&ctx);
            assert_eq!(
                SelArena::quick_eligible_count(&ctx),
                arena.eligible_count(d_max),
                "precheck disagrees with arena filter"
            );
        });
    }

    #[test]
    fn eligibility_is_monotone_in_d() {
        let (clients, states, domains, efc, sfc, snow) = scenario(10, 2, 40.0, 25);
        let fc = FcBuffers::from_rows(&efc, &sfc, 25);
        let ctx = SelectionContext {
            now: 0,
            n: 2,
            d_max: 25,
            clients: &clients,
            states: &states,
            domains: &domains,
            fc: fc.view(),
            incr: None,
            spare_now: &snow,
        };
        let arena = SelArena::build(&ctx);
        let mut prev = 0;
        for d in 1..=25 {
            let count = arena.eligible_count(d);
            assert!(count >= prev, "eligibility shrank at d={d}");
            prev = count;
        }
    }

    #[test]
    fn arena_over_ring_matches_arena_over_fresh_buffers() {
        // same filters whether the window is backed by the mirrored ring
        // (arbitrary head) or flat fresh buffers
        let (clients, states, _domains, efc, sfc, _snow) =
            scenario(8, 2, 120.0, 12);
        let src = crate::selection::ring::SeriesSource {
            energy: efc
                .iter()
                .map(|row| SeriesForecaster::perfect(row.clone()))
                .collect(),
            spare: sfc
                .iter()
                .map(|row| SeriesForecaster::perfect(row.clone()))
                .collect(),
            caps: clients.iter().map(|c| c.capacity()).collect(),
        };
        let mut ring = crate::selection::ring::ForecastRing::new();
        ring.rebuild(&src, 0, 6);
        for step in 1..=5 {
            ring.advance(&src);
            let fresh = FcBuffers::from_source(&src, 0, step, 6);
            let rv = ring.view();
            let fv = fresh.view();
            for p in 0..rv.n_domains() {
                assert_eq!(rv.energy_row(p), fv.energy_row(p), "step {step}");
            }
            for i in 0..rv.n_clients() {
                assert_eq!(rv.spare_row(i), fv.spare_row(i), "step {step}");
            }
        }
        let _ = states;
    }
}
