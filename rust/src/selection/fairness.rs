//! Fair-participation blocklist (paper §4.4).
//!
//! After participating in a round a client is blocked (σ_c = 0, excluded
//! from selection). At each round start, blocked clients are released with
//!
//!   P(c) = (p(c) − ω)^(−α)   if p(c) − ω > 0
//!   P(c) = 1                 otherwise
//!
//! where p(c) is the client's participation count, α controls release
//! speed (paper: α = 1) and ω is periodically set to mean participation so
//! release probabilities do not decay over the training.

use super::ClientRoundState;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Blocklist {
    pub alpha: f64,
    omega: f64,
}

impl Blocklist {
    pub fn new(alpha: f64) -> Self {
        Blocklist { alpha, omega: 0.0 }
    }

    /// release probability for participation count `p`
    pub fn release_probability(&self, p: usize) -> f64 {
        let excess = p as f64 - self.omega;
        if excess > 0.0 {
            excess.powf(-self.alpha).min(1.0)
        } else {
            1.0
        }
    }

    /// Round start: refresh ω and probabilistically release.
    pub fn begin_round(&mut self, states: &mut [ClientRoundState], rng: &mut Rng) {
        if states.is_empty() {
            return;
        }
        self.omega = states.iter().map(|s| s.participation as f64).sum::<f64>()
            / states.len() as f64;
        for s in states.iter_mut() {
            if s.blocked && rng.bool(self.release_probability(s.participation)) {
                s.blocked = false;
            }
        }
    }

    /// Round end: block everyone who participated.
    pub fn block(&mut self, participants: &[usize], states: &mut [ClientRoundState]) {
        for &c in participants {
            states[c].blocked = true;
        }
    }

    pub fn omega(&self) -> f64 {
        self.omega
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn states(participations: &[usize]) -> Vec<ClientRoundState> {
        participations
            .iter()
            .map(|&p| ClientRoundState {
                participation: p,
                sigma: 1.0,
                blocked: true,
            })
            .collect()
    }

    #[test]
    fn release_probability_formula() {
        let mut b = Blocklist::new(1.0);
        let mut s = states(&[0, 2, 4, 6]);
        let mut rng = Rng::new(0);
        b.begin_round(&mut s, &mut rng); // omega = 3
        assert!((b.omega() - 3.0).abs() < 1e-12);
        // p=0,2 -> below/at omega -> release prob 1
        assert_eq!(b.release_probability(0), 1.0);
        assert_eq!(b.release_probability(2), 1.0);
        // p=4 -> (4-3)^-1 = 1; p=6 -> (6-3)^-1 = 1/3
        assert_eq!(b.release_probability(4), 1.0);
        assert!((b.release_probability(6) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn higher_alpha_blocks_longer() {
        let mut b1 = Blocklist::new(1.0);
        let mut b3 = Blocklist::new(3.0);
        b1.omega = 2.0;
        b3.omega = 2.0;
        assert!(b3.release_probability(6) < b1.release_probability(6));
    }

    #[test]
    fn under_participants_always_released() {
        let mut b = Blocklist::new(1.0);
        let mut s = states(&[0, 10, 10, 10]);
        let mut rng = Rng::new(1);
        b.begin_round(&mut s, &mut rng);
        assert!(!s[0].blocked, "under-participant must always be released");
    }

    #[test]
    fn over_participants_released_at_expected_rate() {
        let mut b = Blocklist::new(1.0);
        // omega will be 2.5; p=7 -> prob (4.5)^-1 ≈ 0.222
        let mut released = 0;
        let trials = 4000;
        for seed in 0..trials {
            let mut s = states(&[0, 0, 3, 7]);
            let mut rng = Rng::new(seed);
            b.begin_round(&mut s, &mut rng);
            if !s[3].blocked {
                released += 1;
            }
        }
        let rate = released as f64 / trials as f64;
        assert!((rate - 1.0 / 4.5).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn block_marks_participants() {
        let mut b = Blocklist::new(1.0);
        let mut s = states(&[0, 0, 0]);
        for st in s.iter_mut() {
            st.blocked = false;
        }
        b.block(&[1], &mut s);
        assert!(!s[0].blocked && s[1].blocked && !s[2].blocked);
    }
}
