//! Oort-style statistical utility (Lai et al., OSDI '21), as adopted by
//! the paper for FedZero's σ_c (§4.3):
//!
//!   σ_c = |B_c| · sqrt( (1/|B_c|) Σ_{k∈B_c} loss(k)² )   if p(c) ≥ 1
//!   σ_c = 1                                              otherwise
//!
//! We track the per-sample squared loss through the mean training loss the
//! client reports after each participation (the batch-mean loss is the
//! observable in our protocol; using it as the per-sample estimate is the
//! same approximation Oort's implementations make when only aggregate
//! losses are shipped).

use super::ClientRoundState;

/// Running utility tracker; owned by the server/coordinator.
#[derive(Clone, Debug, Default)]
pub struct UtilityTracker {
    /// last observed mean loss per client (None before first participation)
    last_loss: Vec<Option<f64>>,
}

impl UtilityTracker {
    pub fn new(n_clients: usize) -> Self {
        UtilityTracker { last_loss: vec![None; n_clients] }
    }

    /// Record a completed participation: `mean_loss` over the batches the
    /// client trained this round, `n_samples` its local dataset size.
    /// Returns the new σ_c.
    pub fn update(&mut self, client: usize, mean_loss: f64, n_samples: usize) -> f64 {
        self.last_loss[client] = Some(mean_loss);
        n_samples as f64 * (mean_loss * mean_loss).sqrt()
    }

    /// σ_c per the paper's rule (1.0 until first participation).
    pub fn sigma(&self, client: usize, n_samples: usize, participation: usize) -> f64 {
        match (participation, self.last_loss[client]) {
            (p, Some(loss)) if p >= 1 => {
                n_samples as f64 * (loss * loss).sqrt()
            }
            _ => 1.0,
        }
    }

    /// Checkpoint view: the last observed loss per client (the
    /// tracker's only state).
    pub fn snapshot(&self) -> &[Option<f64>] {
        &self.last_loss
    }

    /// Rebuild a tracker from a [`UtilityTracker::snapshot`] capture.
    pub fn restore(last_loss: Vec<Option<f64>>) -> Self {
        UtilityTracker { last_loss }
    }

    /// Refresh σ in the shared round state (respecting the blocklist,
    /// which forces σ_c = 0).
    pub fn refresh(
        &self,
        states: &mut [ClientRoundState],
        samples: &[usize],
    ) {
        for (i, s) in states.iter_mut().enumerate() {
            s.sigma = if s.blocked {
                0.0
            } else {
                self.sigma(i, samples[i], s.participation)
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_is_one_before_first_participation() {
        let t = UtilityTracker::new(3);
        assert_eq!(t.sigma(0, 500, 0), 1.0);
        assert_eq!(t.sigma(1, 10_000, 0), 1.0);
    }

    #[test]
    fn sigma_scales_with_samples_and_loss() {
        let mut t = UtilityTracker::new(2);
        t.update(0, 2.0, 100);
        t.update(1, 2.0, 400);
        assert!((t.sigma(0, 100, 1) - 200.0).abs() < 1e-9);
        assert!((t.sigma(1, 400, 1) - 800.0).abs() < 1e-9);
        // lower loss -> lower utility
        t.update(1, 0.5, 400);
        assert!((t.sigma(1, 400, 2) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn refresh_zeroes_blocked_clients() {
        let mut t = UtilityTracker::new(2);
        t.update(0, 1.5, 100);
        t.update(1, 1.5, 100);
        let mut states = vec![
            ClientRoundState { participation: 1, sigma: 0.0, blocked: false },
            ClientRoundState { participation: 1, sigma: 0.0, blocked: true },
        ];
        t.refresh(&mut states, &[100, 100]);
        assert!((states[0].sigma - 150.0).abs() < 1e-9);
        assert_eq!(states[1].sigma, 0.0);
    }
}
