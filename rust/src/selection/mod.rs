//! Client selection — the paper's core contribution (§4.3, §4.4) and all
//! six baselines from the evaluation (§5.1).
//!
//! * [`fedzero`] — Algorithm 1: binary search over the round duration `d`,
//!   pre-filters, and the selection MILP solved by [`crate::solver::mip`].
//! * [`oort`] — Oort-style statistical utility tracking (used both as
//!   FedZero's σ_c and by the Oort baselines).
//! * [`fairness`] — the participation blocklist with probabilistic release.
//! * [`baselines`] — Random / Oort (±1.3n over-selection, ±forecast
//!   filtering) and the unconstrained Upper Bound.

pub mod adaptive;
pub mod arena;
pub mod baselines;
pub mod fairness;
pub mod fedzero;
pub mod incr;
pub mod ring;
pub mod semisync;
pub mod oort;

use crate::client::ClientInfo;
use crate::energy::PowerDomain;
use crate::util::rng::Rng;

pub use incr::IncrSelState;
pub use ring::{FcBuffers, FcSource, FcView, ForecastRing};

/// Per-client mutable state the server tracks across rounds.
#[derive(Clone, Debug)]
pub struct ClientRoundState {
    /// p(c): rounds this client has participated in (completed m_min)
    pub participation: usize,
    /// Oort-style statistical utility σ_c
    pub sigma: f64,
    /// on the fairness blocklist?
    pub blocked: bool,
}

impl Default for ClientRoundState {
    fn default() -> Self {
        // paper: σ_c = 1 until the client first participates
        ClientRoundState { participation: 0, sigma: 1.0, blocked: false }
    }
}

/// Everything a strategy may look at when selecting.
///
/// §Perf: forecasts arrive as a borrowed [`FcView`] — contiguous `f32`
/// rows out of the persistent [`ring::ForecastRing`] (or an owned
/// [`FcBuffers`] in tests) — instead of the historical `&[Vec<f64>]`
/// matrices. Strategies and the arena slice these rows directly; nothing
/// is copied per `select()`, and values are widened to f64 only where the
/// solvers do arithmetic.
pub struct SelectionContext<'a> {
    /// current timestep
    pub now: usize,
    /// clients to select per round (n)
    pub n: usize,
    /// max round duration in steps (d_max)
    pub d_max: usize,
    pub clients: &'a [ClientInfo],
    pub states: &'a [ClientRoundState],
    pub domains: &'a [PowerDomain],
    /// forecast window [now, now+d_max): per-domain excess energy
    /// (Wh/step) and per-client spare capacity (batches/step, pre-clamped
    /// to capacity at the source). [`FcView::empty`] for strategies whose
    /// `needs_forecasts()` is false — those must not read it.
    pub fc: FcView<'a>,
    /// §Perf: the engine-owned persistent selection state
    /// ([`incr::IncrSelState`]), advanced in lockstep with the forecast
    /// ring, for strategies whose `uses_selection_state()` is true. When
    /// present it must describe exactly this window (same phase) and the
    /// current `states` liveness; `SelArena` then borrows its reach
    /// structures instead of recomputing them (O(C·d_max) → O(C)), and
    /// the dark-period quick gate drops to O(D). `None` means every
    /// filter is derived freshly from `fc` — bit-identical results.
    pub incr: Option<&'a incr::IncrSelState>,
    /// actual current spare capacity per client (what an energy-agnostic
    /// baseline can observe "right now"). Empty for strategies whose
    /// `needs_spare_now()` is false — those must not read it.
    pub spare_now: &'a [f64],
}

impl<'a> SelectionContext<'a> {
    /// clients that currently have access to excess energy AND spare
    /// compute — the availability condition the paper imposes on the
    /// Random/Oort baselines.
    pub fn available_now(&self) -> Vec<usize> {
        self.clients
            .iter()
            .enumerate()
            .filter(|(i, c)| {
                self.spare_now[*i] > 1e-9 && self.domains[c.domain].has_power(self.now)
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// the paper's line-11 filter: can client `i` reach m_min within
    /// `d` steps per the forecasts, assuming the whole domain budget?
    ///
    /// Evaluated as THE canonical bucketed reachability walk
    /// ([`incr::reach_walk`]) — the single accumulation order every
    /// layer shares (fresh arena builds, the incremental selection
    /// state, this filter), which is what keeps the dark-period gate,
    /// the probe filter, and the ring-patched state bit-equivalent.
    /// Spare rows are pre-clamped to capacity at the forecast source
    /// (see `ring`), so no clamp happens here; zero-energy columns
    /// contribute exactly nothing, so spare values of dark columns are
    /// never read.
    pub fn reachable_min(&self, i: usize, d: usize) -> bool {
        let c = &self.clients[i];
        let r = incr::reach_fresh(
            self.fc.spare_row(i),
            self.fc.energy_row(c.domain),
            c.delta(),
            c.m_min,
            self.fc.phase(),
            incr::bucket_width(self.fc.d_max()),
        );
        r <= d
    }
}

/// What a strategy decided for this round. `PartialEq` so the ring-vs-
/// fresh and parallel-vs-serial equivalence tests can assert decisions
/// are identical field for field.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SelectionDecision {
    /// selected client ids (indices into `ctx.clients`)
    pub clients: Vec<usize>,
    /// expected round duration (FedZero's optimised d; d_max otherwise)
    pub expected_duration: usize,
    /// round ends as soon as this many clients complete m_min
    /// (over-selection baselines set this to n < |clients|)
    pub n_required: usize,
    /// hard cap on this round's duration in steps (normally d_max; the
    /// semi-synchronous extension sets its fixed deadline here)
    pub max_duration: usize,
    /// no feasible selection: skip this step and try again later
    pub wait: bool,
    /// ignore energy/capacity constraints at runtime (Upper Bound)
    pub unconstrained: bool,
}

impl SelectionDecision {
    pub fn wait() -> Self {
        SelectionDecision {
            clients: Vec::new(),
            expected_duration: 0,
            n_required: 0,
            max_duration: 0,
            wait: true,
            unconstrained: false,
        }
    }
}

/// A pluggable selection strategy (one per paper baseline + FedZero).
pub trait Strategy {
    fn name(&self) -> &'static str;
    fn select(&mut self, ctx: &SelectionContext, rng: &mut Rng) -> SelectionDecision;
    /// Does this strategy read the forecast window `ctx.fc`? Strategies
    /// that only look at current availability return false and the
    /// simulator never builds or advances the forecast ring for them
    /// (§Perf: forecast construction dominated idle steps for the
    /// Random/Oort baselines; they receive `FcView::empty()`).
    fn needs_forecasts(&self) -> bool {
        true
    }
    /// Does this strategy read `ctx.spare_now`? Strategies that never
    /// touch current spare capacity (FedZero — its filters are purely
    /// forecast-driven) return false and the simulator skips the O(C)
    /// per-step spare refresh, keeping dark idle polling O(D).
    fn needs_spare_now(&self) -> bool {
        true
    }
    /// Does this strategy consume the engine-owned incremental selection
    /// state (`ctx.incr`)? Only strategies built on `SelArena` (FedZero,
    /// and wrappers around it) benefit; the engine only pays for
    /// maintaining the state when this is true.
    fn uses_selection_state(&self) -> bool {
        false
    }
    /// Hook after a round completes (participants = clients that reached
    /// m_min). FedZero updates its blocklist here.
    fn on_round_end(
        &mut self,
        _participants: &[usize],
        _states: &mut [ClientRoundState],
        _rng: &mut Rng,
    ) {
    }
    /// Cross-round internal state for checkpointing, if the strategy
    /// carries any. Most strategies are pure functions of their config
    /// plus the engine-owned `ClientRoundState`s (FedZero's blocklist ω
    /// is recomputed from those every `on_round_end`) and return `None`;
    /// reactive strategies (`adaptive::ChurnAware`) serialise their
    /// estimators here so a resumed run continues bit-identically.
    fn snapshot_state(&self) -> Option<crate::util::json::Json> {
        None
    }
    /// Restore state captured by [`Strategy::snapshot_state`]. Called
    /// only when the snapshot recorded `Some`; the default errors so a
    /// stateful strategy cannot silently skip restoration.
    fn restore_state(&mut self, _state: &crate::util::json::Json) -> anyhow::Result<()> {
        Err(anyhow::anyhow!(
            "strategy {} recorded checkpoint state but does not implement restore_state",
            self.name()
        ))
    }
}
