//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them from
//! the Rust hot path. Python never runs at request time.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Every entry point was lowered with
//! `return_tuple=True`, so each execution yields a single tuple literal
//! that we decompose.

pub mod manifest;

use std::path::Path;

use anyhow::{anyhow, Context, Result};

pub use manifest::{DType, EntryPoint, Manifest, TensorSpec};

/// A compiled entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    spec: EntryPoint,
    name: String,
}

impl Executable {
    fn load(
        client: &xla::PjRtClient,
        manifest: &Manifest,
        entry: &str,
    ) -> Result<Executable> {
        let path = manifest.artifact_path(entry)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {entry}: {e:?}"))?;
        Ok(Executable {
            exe,
            spec: manifest
                .entry_points
                .get(entry)
                .cloned()
                .ok_or_else(|| anyhow!("no entry point spec for {entry}"))?,
            name: entry.to_string(),
        })
    }

    /// Execute with f32/i32 host slices in manifest order; returns the
    /// decomposed output tuple as raw literals.
    fn run(&self, inputs: &[Input<'_>]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (input, spec)) in
            inputs.iter().zip(&self.spec.inputs).enumerate()
        {
            let lit = input.to_literal(spec).with_context(|| {
                format!("{}: input {i} shape mismatch", self.name)
            })?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{}: execute failed: {e:?}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: transfer failed: {e:?}", self.name))?;
        tuple
            .to_tuple()
            .map_err(|e| anyhow!("{}: output not a tuple: {e:?}", self.name))
    }
}

/// Host-side input tensor (borrowed).
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> Input<'a> {
    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
        match (self, spec.dtype) {
            (Input::F32(data), DType::F32) => {
                if data.len() != spec.elements() {
                    return Err(anyhow!(
                        "want {} f32 elements, got {}",
                        spec.elements(),
                        data.len()
                    ));
                }
                Ok(xla::Literal::vec1(data).reshape(&dims)?)
            }
            (Input::I32(data), DType::I32) => {
                if data.len() != spec.elements() {
                    return Err(anyhow!(
                        "want {} i32 elements, got {}",
                        spec.elements(),
                        data.len()
                    ));
                }
                Ok(xla::Literal::vec1(data).reshape(&dims)?)
            }
            _ => Err(anyhow!("dtype mismatch")),
        }
    }
}

/// Output of one local training step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub params: Vec<f32>,
    pub loss: f32,
    pub correct: i32,
}

/// The loaded model runtime: one compiled executable per entry point.
/// Read-only after `load` — step accounting lives in the caller-owned
/// `fl::ClientTrainState` (a shared interior-mutable counter here would
/// keep the runtime from ever being shared across train workers).
pub struct ModelRuntime {
    pub manifest: Manifest,
    train: Executable,
    eval: Executable,
    init: Executable,
    aggregate: Executable,
}

impl ModelRuntime {
    /// Load + compile all four entry points for `preset`.
    pub fn load(artifact_dir: &Path, preset: &str) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifact_dir, preset)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let train = Executable::load(&client, &manifest, "train_step")?;
        let eval = Executable::load(&client, &manifest, "eval_step")?;
        let init = Executable::load(&client, &manifest, "init")?;
        let aggregate = Executable::load(&client, &manifest, "aggregate")?;
        Ok(ModelRuntime {
            manifest,
            train,
            eval,
            init,
            aggregate,
        })
    }

    pub fn param_count(&self) -> usize {
        self.manifest.param_count
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.batch_size
    }

    /// Initialise a fresh flat parameter vector from a seed.
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let out = self.init.run(&[Input::I32(&[seed])])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// One local FedProx-SGD minibatch step.
    pub fn train_step(
        &self,
        params: &[f32],
        global: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        mu: f32,
    ) -> Result<StepOutput> {
        let out = self.train.run(&[
            Input::F32(params),
            Input::F32(global),
            Input::F32(x),
            Input::I32(y),
            Input::F32(&[lr]),
            Input::F32(&[mu]),
        ])?;
        Ok(StepOutput {
            params: out[0].to_vec::<f32>()?,
            loss: out[1].to_vec::<f32>()?[0],
            correct: out[2].to_vec::<i32>()?[0],
        })
    }

    /// Summed loss + correct count over one eval batch.
    pub fn eval_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, i32)> {
        let out = self.eval.run(&[
            Input::F32(params),
            Input::F32(x),
            Input::I32(y),
        ])?;
        Ok((out[0].to_vec::<f32>()?[0], out[1].to_vec::<i32>()?[0]))
    }

    /// FedAvg over up to `agg_k` flat models (rows borrowed from the
    /// callers' client states); `updates` rows beyond `weights.len()`
    /// are zero-padded.
    pub fn aggregate(
        &self,
        updates: &[&[f32]],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        let k = self.manifest.agg_k;
        let p = self.manifest.param_count;
        if updates.len() != weights.len() {
            return Err(anyhow!("updates/weights length mismatch"));
        }
        if updates.len() > k {
            return Err(anyhow!(
                "got {} updates but aggregation artifact is fixed at K={k}; \
                 aggregate in chunks",
                updates.len()
            ));
        }
        let mut stacked = vec![0.0f32; k * p];
        for (row, u) in updates.iter().enumerate() {
            if u.len() != p {
                return Err(anyhow!("update {row} has wrong param count"));
            }
            stacked[row * p..(row + 1) * p].copy_from_slice(u);
        }
        let mut w = vec![0.0f32; k];
        w[..weights.len()].copy_from_slice(weights);
        let out = self
            .aggregate
            .run(&[Input::F32(&stacked), Input::F32(&w)])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Evaluate accuracy + mean loss over a whole test set (batched; the
    /// trailing partial batch is padded and masked out of the counts).
    pub fn evaluate_dataset(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
    ) -> Result<(f64, f64)> {
        let b = self.manifest.batch_size;
        let d = self.manifest.input_dim;
        let n = ys.len();
        let mut loss_sum = 0.0f64;
        let mut correct = 0i64;
        let mut i = 0;
        while i < n {
            let take = (n - i).min(b);
            if take == b {
                let (l, c) = self.eval_step(
                    params,
                    &xs[i * d..(i + b) * d],
                    &ys[i..i + b],
                )?;
                loss_sum += l as f64;
                correct += c as i64;
            } else {
                // pad by repeating the first sample, then subtract its
                // padded contribution statistically: evaluate pad-only too
                let mut px = xs[i * d..(i + take) * d].to_vec();
                let mut py = ys[i..i + take].to_vec();
                while py.len() < b {
                    px.extend_from_slice(&xs[i * d..i * d + d]);
                    py.push(ys[i]);
                }
                let (l_full, c_full) = self.eval_step(params, &px, &py)?;
                // pad contribution: evaluate the first sample repeated b×
                let mut qx = Vec::with_capacity(b * d);
                let mut qy = Vec::with_capacity(b);
                for _ in 0..b {
                    qx.extend_from_slice(&xs[i * d..i * d + d]);
                    qy.push(ys[i]);
                }
                let (l_pad, c_pad) = self.eval_step(params, &qx, &qy)?;
                let pad = (b - take) as f64;
                loss_sum += l_full as f64 - l_pad as f64 * pad / b as f64;
                correct += c_full as i64
                    - ((c_pad as f64) * pad / b as f64).round() as i64;
            }
            i += take;
        }
        Ok((correct as f64 / n as f64, loss_sum / n as f64))
    }
}
