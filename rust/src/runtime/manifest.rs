//! Parse the AOT manifest emitted by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(anyhow!("unsupported dtype {other}")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        let pair = j.as_arr().ok_or_else(|| anyhow!("spec not an array"))?;
        let dtype = DType::parse(
            pair.first()
                .and_then(|d| d.as_str())
                .ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        let dims = pair
            .get(1)
            .and_then(|d| d.as_arr())
            .ok_or_else(|| anyhow!("missing dims"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dtype, dims })
    }
}

#[derive(Clone, Debug)]
pub struct EntryPoint {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The per-preset manifest: shapes + artifact filenames.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub param_count: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub batch_size: usize,
    pub agg_k: usize,
    pub dir: PathBuf,
    pub artifacts: std::collections::BTreeMap<String, String>,
    pub entry_points: std::collections::BTreeMap<String, EntryPoint>,
}

impl Manifest {
    pub fn load(artifact_dir: &Path, preset: &str) -> Result<Manifest> {
        let path = artifact_dir.join(format!("{preset}_manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let get_usize = |key: &str| -> Result<usize> {
            j.get(key)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest missing {key}"))
        };

        let mut artifacts = std::collections::BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("artifacts") {
            for (k, v) in map {
                artifacts.insert(
                    k.clone(),
                    v.as_str()
                        .ok_or_else(|| anyhow!("bad artifact entry {k}"))?
                        .to_string(),
                );
            }
        }
        let mut entry_points = std::collections::BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("entry_points") {
            for (name, ep) in map {
                let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                    ep.get(key)
                        .and_then(|v| v.as_arr())
                        .ok_or_else(|| anyhow!("{name}: missing {key}"))?
                        .iter()
                        .map(TensorSpec::parse)
                        .collect()
                };
                entry_points.insert(
                    name.clone(),
                    EntryPoint {
                        inputs: parse_list("inputs")?,
                        outputs: parse_list("outputs")?,
                    },
                );
            }
        }

        Ok(Manifest {
            preset: j
                .get("preset")
                .and_then(|v| v.as_str())
                .unwrap_or(preset)
                .to_string(),
            param_count: get_usize("param_count")?,
            input_dim: get_usize("input_dim")?,
            num_classes: get_usize("num_classes")?,
            batch_size: get_usize("batch_size")?,
            agg_k: get_usize("agg_k")?,
            dir: artifact_dir.to_path_buf(),
            artifacts,
            entry_points,
        })
    }

    pub fn artifact_path(&self, entry: &str) -> Result<PathBuf> {
        let file = self
            .artifacts
            .get(entry)
            .ok_or_else(|| anyhow!("no artifact for entry point {entry}"))?;
        Ok(self.dir.join(file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        let text = r#"{
          "preset": "unit", "param_count": 10, "input_dim": 4,
          "num_classes": 2, "batch_size": 3, "agg_k": 5, "hidden": [8],
          "artifacts": {"train_step": "unit_train_step.hlo.txt"},
          "entry_points": {
            "train_step": {
              "inputs": [["f32", [10]], ["i32", [3]]],
              "outputs": [["f32", [10]], ["f32", [1]]]
            }
          }
        }"#;
        std::fs::write(dir.join("unit_manifest.json"), text).unwrap();
    }

    #[test]
    fn loads_and_types_check() {
        let dir = std::env::temp_dir().join("fedzero_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir);
        let m = Manifest::load(&dir, "unit").unwrap();
        assert_eq!(m.param_count, 10);
        assert_eq!(m.batch_size, 3);
        let ep = &m.entry_points["train_step"];
        assert_eq!(ep.inputs.len(), 2);
        assert_eq!(ep.inputs[0].dtype, DType::F32);
        assert_eq!(ep.inputs[1].dtype, DType::I32);
        assert_eq!(ep.inputs[0].elements(), 10);
        assert!(m
            .artifact_path("train_step")
            .unwrap()
            .ends_with("unit_train_step.hlo.txt"));
        assert!(m.artifact_path("nope").is_err());
    }

    #[test]
    fn missing_file_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent"), "x").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
