//! The benchmark/repro harness behind `fedzero repro <id>` — one function
//! per paper table/figure (DESIGN.md §5 maps each to modules).

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use fedzero::client::{ClientProfile, DeviceType, ModelKind};
use fedzero::config::Scenario;
use fedzero::coordinator::{run_experiment, ExperimentSpec, RunReport, StrategyKind};
use fedzero::runtime::ModelRuntime;
use fedzero::scenario::campaign::{run_campaign, run_campaign_durable, CampaignSpec};
use fedzero::util::fsx;
use fedzero::util::json::Json;
use fedzero::util::obs;
use fedzero::util::par;
use fedzero::selection::fedzero::{FedZero, SolverKind};
use fedzero::selection::{ClientRoundState, SelectionContext, Strategy};
use fedzero::solver::mip::{greedy, SelClient, SelInstance};
use fedzero::trace::forecast::{ErrorLevel, SeriesForecaster};
use fedzero::trace::{curtailment, solar};
use fedzero::util::cli::Args;
use fedzero::util::rng::Rng;
use fedzero::util::stats::{self, Histogram};

// ---------------------------------------------------------------------------
// shared plumbing
// ---------------------------------------------------------------------------

fn spec_from_args(args: &Args) -> ExperimentSpec {
    let full = args.flag("full");
    let mut spec = ExperimentSpec {
        preset: args.get_str("preset", "tiny").to_string(),
        scenario: match args.get_str("scenario", "global") {
            "colocated" | "co-located" => Scenario::Colocated,
            _ => Scenario::Global,
        },
        days: args.get_usize("days", if full { 7 } else { 2 }),
        n_clients: args.get_usize("clients", if full { 100 } else { 40 }),
        n_per_round: args.get_usize("n", if full { 10 } else { 6 }),
        d_max: args.get_usize("dmax", 60),
        seed: args.get_usize("seed", 0) as u64,
        dataset_scale: args.get_f64("scale", if full { 1.0 } else { 0.25 }),
        use_mock: args.flag("mock"),
        eval_every: args.get_usize("eval-every", 5),
        eval_subset: args.get_usize("eval-subset", 0),
        artifact_dir: PathBuf::from(args.get_str("artifacts", "artifacts")),
        ..Default::default()
    };
    spec.lr = args.get_f64("lr", 0.05) as f32;
    spec.mu = args.get_f64("mu", 0.01) as f32;
    spec
}

fn run_and_summarize(spec: &ExperimentSpec) -> Result<RunReport> {
    let t0 = Instant::now();
    let report = run_experiment(spec)?;
    obs::log!(info, 
        "  {:<36} {}  [{:.1}s wall, {} steps, select {:.1} ms]",
        report.spec_name,
        report.metrics.summary(""),
        t0.elapsed().as_secs_f64(),
        report.steps_executed,
        report.select_time_ms,
    );
    Ok(report)
}

fn fmt_opt_days(x: Option<f64>) -> String {
    x.map(|d| format!("{d:.1} d")).unwrap_or_else(|| "-".into())
}

fn fmt_opt_kwh(x: Option<f64>) -> String {
    x.map(|k| format!("{k:.1} kWh")).unwrap_or_else(|| "-".into())
}

// ---------------------------------------------------------------------------
// train / selftest
// ---------------------------------------------------------------------------

pub fn cmd_train(args: &Args) -> Result<()> {
    let mut spec = spec_from_args(args);
    spec.strategy = StrategyKind::parse(args.get_str("strategy", "FedZero"))?;
    // --checkpoint DIR keeps a write-ahead journal + snapshots there;
    // --resume continues a killed run from the same directory. The
    // snapshot cadence shapes the journal bytes, so pass the same
    // --snapshot-every on resume as on the original run.
    if let Some(dir) = args.get("checkpoint") {
        spec.checkpoint_dir = Some(PathBuf::from(dir));
        spec.snapshot_every = args.get_usize("snapshot-every", 5);
        spec.resume = args.flag("resume");
    } else if args.flag("resume") {
        return Err(anyhow!("--resume needs --checkpoint DIR"));
    }
    let report = run_and_summarize(&spec)?;
    if let Some(path) = args.get("out") {
        report.metrics.save(std::path::Path::new(path))?;
        obs::log!(info, "wrote {path}");
    }
    Ok(())
}

pub fn cmd_selftest(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let preset = args.get_str("preset", "tiny");
    obs::log!(info, "loading {preset} artifacts from {dir:?}...");
    let rt = ModelRuntime::load(&dir, preset)?;
    let p = rt.param_count();
    let b = rt.batch_size();
    let d = rt.manifest.input_dim;
    obs::log!(info, "  param_count={p} batch={b} dim={d}");

    let params = rt.init_params(7)?;
    assert_eq!(params.len(), p);
    let norm: f32 = params.iter().map(|x| x * x).sum::<f32>().sqrt();
    obs::log!(info, "  init ok, |params| = {norm:.3}");

    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..b)
        .map(|_| rng.below(rt.manifest.num_classes) as i32)
        .collect();
    let t0 = Instant::now();
    let out = rt.train_step(&params, &params, &x, &y, 0.05, 0.01)?;
    let first = t0.elapsed();
    obs::log!(info, "  train_step ok: loss={:.4} correct={}", out.loss, out.correct);

    // loss must decrease over repeated steps on the same batch
    let mut pcur = params.clone();
    let mut last_loss = f32::INFINITY;
    for _ in 0..8 {
        let o = rt.train_step(&pcur, &params, &x, &y, 0.05, 0.01)?;
        pcur = o.params;
        last_loss = o.loss;
    }
    if last_loss >= out.loss {
        return Err(anyhow!("loss did not decrease: {} -> {last_loss}", out.loss));
    }
    obs::log!(info, "  8 steps on one batch: loss {:.4} -> {last_loss:.4}", out.loss);

    let t1 = Instant::now();
    let iters = 50;
    let mut pp = params.clone();
    for _ in 0..iters {
        pp = rt.train_step(&pp, &params, &x, &y, 0.05, 0.01)?.params;
    }
    let per = t1.elapsed().as_secs_f64() / iters as f64;
    obs::log!(info, 
        "  train_step latency: first {:.1} ms, steady {:.3} ms",
        first.as_secs_f64() * 1e3,
        per * 1e3
    );

    let (loss_sum, correct) = rt.eval_step(&params, &x, &y)?;
    obs::log!(info, "  eval_step ok: loss_sum={loss_sum:.3} correct={correct}");

    let agg = rt.aggregate(&[params.as_slice(), pcur.as_slice()], &[1.0, 1.0])?;
    assert_eq!(agg.len(), p);
    obs::log!(info, "  aggregate ok");
    obs::log!(info, "selftest PASSED");
    Ok(())
}

// ---------------------------------------------------------------------------
// repro dispatch
// ---------------------------------------------------------------------------

pub fn cmd_repro(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("repro needs a figure/table id"))?;
    match which {
        "fig1" => fig1(args),
        "fig2" | "fig4" => fig2_fig4(args),
        "table2" => table2(args),
        "fig5" | "table3" => fig5_table3(args),
        "fig6" | "table4" => fig6_table4(args),
        "fig7" => fig7(args),
        "fig8" => fig8(args),
        "campaign" => cmd_campaign(args),
        "all" => {
            for id in ["fig1", "fig2", "table2", "fig5", "fig6", "fig7", "fig8"] {
                let mut a = args.clone();
                a.positional = vec![id.to_string()];
                cmd_repro(&a)?;
                obs::log!(info);
            }
            Ok(())
        }
        other => Err(anyhow!("unknown repro target {other}")),
    }
}

// --- Fig 1: CAISO curtailment ----------------------------------------------

fn fig1(_args: &Args) -> Result<()> {
    obs::log!(info, "=== Fig 1: quarterly wind+solar curtailment, CAISO-style model ===");
    let series = curtailment::caiso_series(2015, 2024, 1);
    obs::log!(info, "{:>6} {:>4} {:>14}", "year", "qtr", "curtailed GWh");
    for r in &series {
        let bar = "#".repeat((r.curtailment_gwh / 25.0) as usize);
        obs::log!(info, "{:>6} {:>4} {:>14.0}  {bar}", r.year, r.quarter, r.curtailment_gwh);
    }
    for y in [2018, 2020, 2022, 2024] {
        obs::log!(info, "  annual {y}: {:.2} TWh", curtailment::annual_twh(&series, y));
    }
    obs::log!(info, "(paper cites >2.4 TWh CAISO solar curtailment in 2022 — ~7% of its solar)");
    Ok(())
}

// --- Fig 2 / Fig 4: excess power + client availability ----------------------

fn fig2_fig4(args: &Args) -> Result<()> {
    obs::log!(info, "=== Fig 2/4: excess power and client availability ===");
    for scenario in [Scenario::Global, Scenario::Colocated] {
        let sites = scenario.sites();
        let days = args.get_usize("days", 7);
        let steps = days * 24 * 60;
        let mut rng = Rng::new(args.get_usize("seed", 0) as u64);
        let regional = match scenario {
            Scenario::Colocated => Some(solar::regional_cloud_series(
                steps, 1.0, 0.4, &mut rng,
            )),
            _ => None,
        };
        obs::log!(info, "\n-- {} scenario ({} days) --", scenario.name(), days);
        obs::log!(info, "{:<14} {:>10} {:>12}  hourly profile (day 1)", "domain", "peak W", "kWh/day");
        for site in &sites {
            let trace = solar::generate(
                site,
                800.0,
                scenario.start_day_of_year(),
                steps,
                1.0,
                &mut rng,
                regional.as_deref(),
            );
            let peak = stats::max(&trace);
            let kwh_day =
                trace.iter().sum::<f64>() / 60.0 / 1000.0 / days as f64;
            // hourly sparkline of day 1
            let mut h = Histogram::new(0.0, 24.0, 24);
            for (i, &p) in trace[..1440.min(trace.len())].iter().enumerate() {
                for _ in 0..(p / 40.0) as usize {
                    h.push(i as f64 / 60.0);
                }
            }
            obs::log!(info, 
                "{:<14} {:>10.0} {:>12.2}  {}",
                site.name,
                peak,
                kwh_day,
                h.sparkline()
            );
        }
    }
    obs::log!(info, "\n(global: staggered availability around the clock; co-located: synchronized)");
    Ok(())
}

// --- Table 2: client profiles ------------------------------------------------

fn table2(_args: &Args) -> Result<()> {
    obs::log!(info, "=== Table 2: client types (max energy, samples/minute) ===");
    obs::log!(info, 
        "{:<8} {:>10} {:>14} {:>16} {:>8} {:>8}",
        "type", "max W", "DenseNet-121", "EfficientNet-B1", "LSTM", "KWT-1"
    );
    for device in DeviceType::ALL {
        obs::log!(info, 
            "{:<8} {:>10.0} {:>14.0} {:>16.0} {:>8.0} {:>8.0}",
            device.name(),
            device.max_power_w(),
            device.samples_per_min(ModelKind::Vision),
            device.samples_per_min(ModelKind::ImageNet),
            device.samples_per_min(ModelKind::Seq),
            device.samples_per_min(ModelKind::Speech),
        );
    }
    obs::log!(info, "\nderived per-batch constants (batch=10, 1-min steps):");
    obs::log!(info, "{:<8} {:>18} {:>16}", "type", "m_c (batches/min)", "δ_c (Wh/batch)");
    for device in DeviceType::ALL {
        let p = ClientProfile::new(device, ModelKind::Vision, 10, 1.0);
        obs::log!(info, 
            "{:<8} {:>18.1} {:>16.4}",
            device.name(),
            p.batches_per_step,
            p.wh_per_batch
        );
    }
    Ok(())
}

// --- Fig 5 / Table 3: main results -------------------------------------------

fn strategies_for(args: &Args) -> Vec<StrategyKind> {
    if args.flag("full") || args.flag("all-strategies") {
        StrategyKind::ALL.to_vec()
    } else {
        // Upper bound is excluded by default: unconstrained, it executes
        // 5-10x more training steps than every other strategy combined
        // (it is an Appendix-A row in the paper, too). --full restores it.
        vec![
            StrategyKind::Random,
            StrategyKind::RandomOver,
            StrategyKind::OortOver,
            StrategyKind::OortFc,
            StrategyKind::FedZero,
        ]
    }
}

fn fig5_table3(args: &Args) -> Result<()> {
    obs::log!(info, "=== Fig 5 + Table 3: training progress / time+energy-to-accuracy ===");
    let scenarios = [Scenario::Global, Scenario::Colocated];
    let strategies = strategies_for(args);
    for scenario in scenarios {
        obs::log!(info, "\n-- {} scenario, preset {} --", scenario.name(), args.get_str("preset", "tiny"));
        let mut reports: Vec<RunReport> = Vec::new();
        for strategy in &strategies {
            let mut spec = spec_from_args(args);
            spec.scenario = scenario;
            spec.strategy = *strategy;
            reports.push(run_and_summarize(&spec)?);
        }
        // Target accuracy: the paper uses the Random baseline's top
        // accuracy. On our faster-saturating synthetic tasks every
        // strategy lands within eval noise of the same plateau, so the
        // comparable operating point is 95% of Random's best — reached
        // during the convergence ramp, where the strategies actually
        // differ (documented in EXPERIMENTS.md).
        let target = reports
            .iter()
            .find(|r| r.strategy == StrategyKind::Random)
            .map(|r| r.metrics.best_accuracy())
            .unwrap_or(0.0)
            * 0.95;
        obs::log!(info, "\n  Table 3 rows (target accuracy {:.2}%):", target * 100.0);
        obs::log!(info, 
            "  {:<14} {:>10} {:>12} {:>14} {:>12}",
            "approach", "best acc", "time-to-acc", "energy-to-acc", "mean round"
        );
        for r in &reports {
            obs::log!(info, 
                "  {:<14} {:>9.2}% {:>12} {:>14} {:>9.1} min",
                r.strategy.name(),
                r.metrics.best_accuracy() * 100.0,
                fmt_opt_days(r.metrics.time_to_accuracy(target)),
                fmt_opt_kwh(r.metrics.energy_to_accuracy(target)),
                r.metrics.mean_round_duration_min(),
            );
        }
        // Fig 5 series: accuracy over sim-days per strategy
        obs::log!(info, "\n  Fig 5 series (accuracy % by sim-day):");
        for r in &reports {
            let pts: Vec<String> = r
                .metrics
                .evals
                .iter()
                .map(|e| {
                    format!(
                        "({:.2},{:.1})",
                        e.step as f64 / 1440.0,
                        e.accuracy * 100.0
                    )
                })
                .collect();
            obs::log!(info, "    {:<14} {}", r.strategy.name(), pts.join(" "));
        }
    }
    Ok(())
}

// --- Fig 6 / Table 4: fairness -----------------------------------------------

fn fig6_table4(args: &Args) -> Result<()> {
    obs::log!(info, "=== Fig 6 + Table 4: fairness of participation ===");
    let strategies = [
        StrategyKind::Random,
        StrategyKind::Oort,
        StrategyKind::FedZero,
    ];
    for unlimited in [None, Some(0usize)] {
        let label = match unlimited {
            None => "(a) base scenario".to_string(),
            Some(d) => format!("(b) domain {d} (Berlin) unlimited"),
        };
        obs::log!(info, "\n-- {label} --");
        let mut rows = Vec::new();
        for strategy in strategies {
            let mut spec = spec_from_args(args);
            spec.scenario = Scenario::Global;
            spec.strategy = strategy;
            spec.unlimited_domain = unlimited;
            let report = run_and_summarize(&spec)?;
            let (per_domain, between_std) = report
                .metrics
                .participation_by_domain(&report.client_domains, report.n_domains);
            let shares: Vec<String> = per_domain
                .iter()
                .map(|(m, s)| format!("{:.1}±{:.1}", m * 100.0, s * 100.0))
                .collect();
            obs::log!(info, 
                "    {:<10} between-domain std {:.2}%  per-domain %: {}",
                strategy.name(),
                between_std * 100.0,
                shares.join(" ")
            );
            rows.push((strategy, report));
        }
        if unlimited.is_some() {
            obs::log!(info, "\n  Table 4 (unlimited Berlin):");
            obs::log!(info, 
                "  {:<10} {:>10} {:>12} {:>14}",
                "approach", "best acc", "time-to-acc", "energy-to-acc"
            );
            let target = rows
                .iter()
                .find(|(s, _)| *s == StrategyKind::Random)
                .map(|(_, r)| r.metrics.best_accuracy())
                .unwrap_or(0.0)
                * 0.95;
            for (s, r) in &rows {
                obs::log!(info, 
                    "  {:<10} {:>9.2}% {:>12} {:>14}",
                    s.name(),
                    r.metrics.best_accuracy() * 100.0,
                    fmt_opt_days(r.metrics.time_to_accuracy(target)),
                    fmt_opt_kwh(r.metrics.energy_to_accuracy(target)),
                );
            }
        }
    }
    Ok(())
}

// --- Fig 7: forecast error robustness ----------------------------------------

fn fig7(args: &Args) -> Result<()> {
    obs::log!(info, "=== Fig 7: robustness against forecasting errors ===");
    let variants: [(&str, ErrorLevel, ErrorLevel); 3] = [
        ("FedZero w/ error", ErrorLevel::Realistic, ErrorLevel::Realistic),
        ("FedZero w/o error", ErrorLevel::Perfect, ErrorLevel::Perfect),
        ("FedZero w/ error (no load fc)", ErrorLevel::Realistic, ErrorLevel::Unavailable),
    ];
    let mut reports = Vec::new();
    for (name, energy_err, load_err) in variants {
        let mut spec = spec_from_args(args);
        spec.scenario = Scenario::Global;
        spec.strategy = StrategyKind::FedZero;
        spec.energy_error = energy_err;
        spec.load_error = load_err;
        let r = run_and_summarize(&spec)?;
        reports.push((name, r));
    }
    // convergence + round duration distribution
    let target = reports
        .iter()
        .map(|(_, r)| r.metrics.best_accuracy())
        .fold(f64::INFINITY, f64::min)
        * 0.95;
    obs::log!(info, "\n  {:<30} {:>10} {:>12} {:>14} {:>12}", "variant", "best acc", "time-to-acc", "energy-to-acc", "mean round");
    for (name, r) in &reports {
        obs::log!(info, 
            "  {:<30} {:>9.2}% {:>12} {:>14} {:>9.1} min",
            name,
            r.metrics.best_accuracy() * 100.0,
            fmt_opt_days(r.metrics.time_to_accuracy(target)),
            fmt_opt_kwh(r.metrics.energy_to_accuracy(target)),
            r.metrics.mean_round_duration_min(),
        );
    }
    obs::log!(info, "\n  round duration distributions (min):");
    for (name, r) in &reports {
        let durs = r.metrics.round_durations_min();
        let mut h = Histogram::new(0.0, 60.0, 12);
        for &d in &durs {
            h.push(d);
        }
        obs::log!(info, 
            "    {:<30} p50 {:>5.1}  p95 {:>5.1}  {}",
            name,
            stats::percentile(&durs, 50.0),
            stats::percentile(&durs, 95.0),
            h.sparkline()
        );
    }
    Ok(())
}

// --- campaign: declarative multi-scenario sweeps -----------------------------

/// `fedzero repro campaign <spec.json>` (also reachable as the top-level
/// `fedzero campaign <spec.json>`): expand the spec's grid, drain the
/// cells across workers, print a summary table, and write the
/// deterministic machine-readable report (default CAMPAIGN_report.json;
/// byte-identical for any --workers value).
pub fn cmd_campaign(args: &Args) -> Result<()> {
    let path = args
        .positional
        .iter()
        .find(|p| p.as_str() != "campaign")
        .ok_or_else(|| {
            anyhow!(
                "campaign needs a spec file: fedzero repro campaign <spec.json> \
                 [--workers N] [--out FILE] [--resume DIR] (builtin: pass 'smoke')"
            )
        })?;
    let spec = if path.as_str() == "smoke" {
        CampaignSpec::smoke()
    } else {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading campaign spec {path}"))?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {path}: {e}"))?;
        CampaignSpec::from_json(&json).with_context(|| format!("invalid spec {path}"))?
    };
    let workers = args.get_usize("workers", par::threads());
    let cells = spec.expand();
    obs::log!(info, 
        "=== campaign {:?}: {} cells across {} workers ===",
        spec.name,
        cells.len(),
        workers
    );
    // --resume DIR records each finished cell under DIR and, on a rerun,
    // reloads the completed ones instead of recomputing — the report
    // stays byte-identical to a fresh single-pass run
    let run = match args.get("resume") {
        Some(dir) => run_campaign_durable(&spec, workers, std::path::Path::new(dir))?,
        None => run_campaign(&spec, workers)?,
    };
    obs::log!(info, 
        "\n{:<52} {:>6} {:>9} {:>10} {:>10} {:>9} {:>7}",
        "cell", "rounds", "best acc", "tta (d)", "kWh", "waste", "jain"
    );
    for r in &run.results {
        obs::log!(info, 
            "{:<52} {:>6} {:>8.2}% {:>10} {:>10.2} {:>9.2} {:>7.3}",
            r.cell.label,
            r.rounds,
            r.best_accuracy * 100.0,
            r.time_to_target_days
                .map(|d| format!("{d:.2}"))
                .unwrap_or_else(|| "-".into()),
            r.energy_kwh,
            r.wasted_kwh,
            r.fairness_jain,
        );
    }
    obs::log!(info, 
        "\n{} cells in {:.1}s ({:.2} cells/s), trace memoization {}/{} hits ({:.0}%)",
        run.results.len(),
        run.wall_s,
        run.results.len() as f64 / run.wall_s.max(1e-9),
        run.memo_hits,
        run.memo_hits + run.memo_misses,
        run.memo_hit_rate() * 100.0,
    );
    let out = args.get_str("out", "CAMPAIGN_report.json");
    // atomic (temp + rename): a crash mid-write can't leave a torn report
    fsx::write_atomic(
        std::path::Path::new(out),
        run.report_json().to_string_pretty().as_bytes(),
    )?;
    obs::log!(info, "wrote {out}");
    Ok(())
}

// --- Fig 8: overhead & scalability -------------------------------------------

/// Build a synthetic selection instance of the given scale.
pub fn synth_instance(
    n_clients: usize,
    n_domains: usize,
    horizon: usize,
    n_select: usize,
    seed: u64,
) -> SelInstance {
    let mut rng = Rng::new(seed);
    let clients = (0..n_clients)
        .map(|_| {
            let m_min = rng.range_f64(5.0, 40.0);
            SelClient {
                domain: rng.below(n_domains),
                sigma: rng.range_f64(0.1, 10.0),
                delta: rng.range_f64(0.05, 0.5),
                m_min,
                m_max: m_min * 5.0,
                spare: (0..horizon)
                    .map(|_| rng.range_f64(0.0, 40.0) as f32)
                    .collect(),
            }
        })
        .collect();
    let energy = (0..n_domains)
        .map(|_| {
            (0..horizon).map(|_| rng.range_f64(0.0, 14.0) as f32).collect()
        })
        .collect();
    SelInstance { n: n_select, clients, energy }
}

fn fig8(args: &Args) -> Result<()> {
    obs::log!(info, "=== Fig 8: selection overhead & scalability ===");
    let full = args.flag("full");
    let seed = args.get_usize("seed", 0) as u64;

    // (a) full Algorithm-1 style run over increasing client counts
    obs::log!(info, "\n(a) selection runtime vs number of clients (greedy solver)");
    obs::log!(info, "{:>10} {:>10} {:>10} {:>12}", "clients", "domains", "steps", "runtime");
    let sizes: Vec<(usize, usize, usize)> = if full {
        vec![
            (100, 10, 60),
            (1_000, 100, 60),
            (10_000, 1_000, 60),
            (100_000, 10_000, 60),
            (100_000, 100_000, 1_440),
        ]
    } else {
        vec![(100, 10, 60), (1_000, 100, 60), (10_000, 1_000, 60)]
    };
    for (c, p, t) in sizes {
        let inst = synth_instance(c, p, t, 10, seed);
        let t0 = Instant::now();
        let sol = greedy(&inst, 1);
        let dt = t0.elapsed();
        obs::log!(info, 
            "{:>10} {:>10} {:>10} {:>12}",
            c,
            p,
            t,
            format!("{:.3} s", dt.as_secs_f64())
        );
        assert!(sol.chosen.len() <= 10);
    }

    // (b) single solve for different domain counts
    obs::log!(info, "\n(b) single-selection runtime vs #domains (10k clients)");
    obs::log!(info, "{:>10} {:>12}", "domains", "runtime");
    let domain_counts = if full {
        vec![10, 100, 1_000, 10_000, 100_000]
    } else {
        vec![10, 100, 1_000]
    };
    for p in domain_counts {
        let clients = if full { 100_000 } else { 10_000 };
        let inst = synth_instance(clients, p.min(clients), 60, 10, seed + 1);
        let t0 = Instant::now();
        let _ = greedy(&inst, 1);
        obs::log!(info, "{:>10} {:>12}", p, format!("{:.3} s", t0.elapsed().as_secs_f64()));
    }

    // Overhead at evaluation scale, matching the paper's "0.1 s at
    // 100 clients / 10 domains / 60 steps".
    let inst = synth_instance(100, 10, 60, 10, seed + 2);
    let t0 = Instant::now();
    let reps = 20;
    for _ in 0..reps {
        let _ = greedy(&inst, 1);
    }
    obs::log!(info, 
        "\nevaluation-scale selection (100 clients, 10 domains, 60 steps): {:.1} ms",
        t0.elapsed().as_secs_f64() * 1e3 / reps as f64
    );
    let _ = black_box_sink();
    Ok(())
}

fn black_box_sink() -> usize {
    std::hint::black_box(0)
}

// keep FedZero/Strategy imports used even in reduced builds
#[allow(dead_code)]
fn _typecheck_strategy_imports(
    ctx: &SelectionContext,
    states: &[ClientRoundState],
    fc: &SeriesForecaster,
) {
    let mut fz = FedZero::new(SolverKind::Greedy);
    let mut rng = Rng::new(0);
    let _ = fz.select(ctx, &mut rng);
    let _ = (states.len(), fc.len());
}
