//! Synthetic federated datasets (substitutes for CIFAR-100, Tiny ImageNet,
//! Shakespeare and Google Speech Commands — DESIGN.md §2).
//!
//! What matters to the paper's results is not pixel content but the
//! *statistical shape* of the federation: label skew (Dirichlet α=0.5 for
//! the vision pairs), extreme per-client sample imbalance (Shakespeare:
//! 2365±4674 samples, min 730 / max 27950), and speaker-partitioning
//! (Google Speech). [`synth`] builds learnable Gaussian-prototype tasks at
//! the model preset's dimensions; [`partition`] reproduces the skews.

pub mod partition;
pub mod synth;

pub use partition::{dirichlet_partition, imbalanced_partition, Partition};
pub use synth::{SynthConfig, SynthDataset};
