//! Federated partitioning of a dataset across clients.
//!
//! * [`dirichlet_partition`] — label-skew non-iid split (Hsu et al. 2019,
//!   used by the paper with α = 0.5 for CIFAR-100 / Tiny ImageNet): per
//!   class, a Dirichlet(α) draw over clients decides which share of that
//!   class's samples each client receives, skewing both class mix and
//!   per-client sample counts.
//! * [`imbalanced_partition`] — heavy log-normal sample imbalance with
//!   per-client label preference (Shakespeare: each client is one speaker
//!   role; 2365±4674 samples, min 730 / max 27950 in the paper — we match
//!   the shape at a configurable scale).

use crate::util::rng::Rng;

/// Per-client sample indices into the training split.
#[derive(Clone, Debug)]
pub struct Partition {
    pub clients: Vec<Vec<usize>>,
}

impl Partition {
    pub fn sizes(&self) -> Vec<usize> {
        self.clients.iter().map(|c| c.len()).collect()
    }

    pub fn total(&self) -> usize {
        self.clients.iter().map(|c| c.len()).sum()
    }

    /// every sample assigned at most once
    pub fn is_disjoint(&self) -> bool {
        let mut seen = std::collections::HashSet::new();
        for c in &self.clients {
            for &i in c {
                if !seen.insert(i) {
                    return false;
                }
            }
        }
        true
    }
}

/// Dirichlet(α) label-skew partition.
pub fn dirichlet_partition(
    labels: &[i32],
    n_clients: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Partition {
    let n_classes = labels.iter().map(|&y| y as usize).max().unwrap_or(0) + 1;
    // bucket sample ids per class, shuffled
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (i, &y) in labels.iter().enumerate() {
        per_class[y as usize].push(i);
    }
    let mut clients = vec![Vec::new(); n_clients];
    for bucket in per_class.iter_mut() {
        rng.shuffle(bucket);
        let shares = rng.dirichlet_sym(alpha, n_clients);
        // cumulative split of the bucket by shares
        let n = bucket.len();
        let mut start = 0usize;
        let mut acc = 0.0;
        for (c, &share) in shares.iter().enumerate() {
            acc += share;
            let end = if c == n_clients - 1 {
                n
            } else {
                ((acc * n as f64).round() as usize).clamp(start, n)
            };
            clients[c].extend_from_slice(&bucket[start..end]);
            start = end;
        }
    }
    // give empty clients one sample from the largest client so every client
    // is trainable (the paper's clients all hold data)
    for c in 0..n_clients {
        if clients[c].is_empty() {
            let donor = (0..n_clients)
                .max_by_key(|&d| clients[d].len())
                .unwrap();
            if let Some(sample) = clients[donor].pop() {
                clients[c].push(sample);
            }
        }
    }
    Partition { clients }
}

/// Log-normal sample-count imbalance + preferred-class skew.
///
/// `count_range`: (min, max) samples per client; counts follow a log-normal
/// shaped to that range (paper's Shakespeare: 730..27950).
pub fn imbalanced_partition(
    labels: &[i32],
    n_clients: usize,
    count_range: (usize, usize),
    rng: &mut Rng,
) -> Partition {
    let n_classes = labels.iter().map(|&y| y as usize).max().unwrap_or(0) + 1;
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (i, &y) in labels.iter().enumerate() {
        per_class[y as usize].push(i);
    }
    for bucket in per_class.iter_mut() {
        rng.shuffle(bucket);
    }
    let mut cursor = vec![0usize; n_classes];

    // draw target counts: lognormal(μ=0, σ=1.2) rescaled into range
    let (lo, hi) = count_range;
    let draws: Vec<f64> = (0..n_clients).map(|_| rng.lognormal(0.0, 1.2)).collect();
    let dmin = draws.iter().cloned().fold(f64::INFINITY, f64::min);
    let dmax = draws.iter().cloned().fold(0.0f64, f64::max);
    let counts: Vec<usize> = draws
        .iter()
        .map(|&x| {
            let t = if dmax > dmin { (x - dmin) / (dmax - dmin) } else { 0.5 };
            lo + (t * (hi - lo) as f64).round() as usize
        })
        .collect();

    let mut clients = vec![Vec::new(); n_clients];
    for (c, &want) in counts.iter().enumerate() {
        // each client prefers 2-4 classes ("speaker style")
        let n_pref = rng.range(2, 5.min(n_classes + 1)).min(n_classes);
        let prefs = rng.sample_indices(n_classes, n_pref);
        let mut got = 0usize;
        let mut spin = 0usize;
        while got < want && spin < want * 4 {
            spin += 1;
            // 80% from preferred classes, 20% uniform
            let class = if rng.bool(0.8) {
                prefs[rng.below(prefs.len())]
            } else {
                rng.below(n_classes)
            };
            if cursor[class] < per_class[class].len() {
                clients[c].push(per_class[class][cursor[class]]);
                cursor[class] += 1;
                got += 1;
            } else if per_class.iter().zip(&cursor).all(|(b, &k)| k >= b.len()) {
                break; // dataset exhausted
            }
        }
    }
    // guarantee non-empty clients
    for c in 0..n_clients {
        if clients[c].is_empty() {
            let donor =
                (0..n_clients).max_by_key(|&d| clients[d].len()).unwrap();
            if let Some(sample) = clients[donor].pop() {
                clients[c].push(sample);
            }
        }
    }
    Partition { clients }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn labels(n: usize, classes: usize, rng: &mut Rng) -> Vec<i32> {
        (0..n).map(|_| rng.below(classes) as i32).collect()
    }

    #[test]
    fn dirichlet_assigns_everything_disjointly() {
        let mut rng = Rng::new(1);
        let y = labels(5000, 10, &mut rng);
        let p = dirichlet_partition(&y, 20, 0.5, &mut rng);
        assert!(p.is_disjoint());
        assert_eq!(p.total(), 5000);
        assert!(p.sizes().iter().all(|&s| s > 0));
    }

    #[test]
    fn dirichlet_low_alpha_skews_labels() {
        let mut rng = Rng::new(2);
        let y = labels(10_000, 10, &mut rng);
        let p = dirichlet_partition(&y, 10, 0.1, &mut rng);
        // with α=0.1 most clients should be dominated by few classes
        let mut dominated = 0;
        for c in &p.clients {
            let mut counts = [0usize; 10];
            for &i in c {
                counts[y[i] as usize] += 1;
            }
            let max = *counts.iter().max().unwrap();
            if (max as f64) > 0.4 * c.len() as f64 {
                dominated += 1;
            }
        }
        assert!(dominated >= 6, "dominated={dominated}");
    }

    #[test]
    fn dirichlet_high_alpha_is_balanced() {
        let mut rng = Rng::new(3);
        let y = labels(10_000, 10, &mut rng);
        let p = dirichlet_partition(&y, 10, 100.0, &mut rng);
        let sizes: Vec<f64> = p.sizes().iter().map(|&s| s as f64).collect();
        assert!(stats::std(&sizes) / stats::mean(&sizes) < 0.15);
    }

    #[test]
    fn imbalanced_matches_range_and_is_skewed() {
        let mut rng = Rng::new(4);
        let y = labels(60_000, 30, &mut rng);
        let p = imbalanced_partition(&y, 50, (30, 1200), &mut rng);
        assert!(p.is_disjoint());
        let sizes: Vec<f64> = p.sizes().iter().map(|&s| s as f64).collect();
        assert!(stats::min(&sizes) >= 1.0);
        assert!(stats::max(&sizes) <= 1200.0 + 1.0);
        // heavy imbalance: std comparable to mean (paper: 4674 vs 2365)
        assert!(
            stats::std(&sizes) > 0.5 * stats::mean(&sizes),
            "std {} mean {}",
            stats::std(&sizes),
            stats::mean(&sizes)
        );
    }

    #[test]
    fn imbalanced_clients_have_label_preference() {
        let mut rng = Rng::new(5);
        let y = labels(40_000, 20, &mut rng);
        let p = imbalanced_partition(&y, 30, (100, 800), &mut rng);
        let mut skewed = 0;
        for c in &p.clients {
            let mut counts = vec![0usize; 20];
            for &i in c {
                counts[y[i] as usize] += 1;
            }
            counts.sort_unstable_by(|a, b| b.cmp(a));
            let top4: usize = counts[..4].iter().sum();
            if top4 as f64 > 0.6 * c.len() as f64 {
                skewed += 1;
            }
        }
        assert!(skewed > 20, "skewed={skewed}");
    }
}
