//! Gaussian-prototype classification data.
//!
//! Per class k we draw a prototype p_k ~ N(0, I); a sample of class k is
//! tanh(M·(p_k + ν·ε)) with a fixed random mixing matrix M shared by all
//! classes — separable enough that an MLP learns it, non-trivial enough
//! (nonlinear mixing, overlapping clusters) that learning takes many
//! rounds and data heterogeneity matters, mirroring the role of the
//! paper's real datasets.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub dim: usize,
    pub classes: usize,
    pub n_train: usize,
    pub n_test: usize,
    /// within-class noise scale ν (larger = harder task)
    pub noise: f64,
    pub seed: u64,
}

impl SynthConfig {
    pub fn new(dim: usize, classes: usize, n_train: usize, n_test: usize) -> Self {
        SynthConfig { dim, classes, n_train, n_test, noise: 0.9, seed: 0 }
    }
}

/// Row-major dataset; features f32 (the dtype the HLO artifacts expect).
#[derive(Clone, Debug)]
pub struct SynthDataset {
    pub dim: usize,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl SynthDataset {
    pub fn generate(cfg: &SynthConfig) -> SynthDataset {
        let mut rng = Rng::new(cfg.seed ^ 0x5EED_DA7A);
        let d = cfg.dim;
        // prototypes and a shared mixing matrix
        let protos: Vec<Vec<f64>> = (0..cfg.classes)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let mix: Vec<f64> = {
            // sparse-ish random rotation: M[i][j], row-major
            let scale = 1.0 / (d as f64).sqrt();
            (0..d * d).map(|_| rng.normal() * scale).collect()
        };

        let sample = |class: usize, rng: &mut Rng, out: &mut Vec<f32>| {
            let p = &protos[class];
            let mut raw = vec![0.0f64; d];
            for (i, r) in raw.iter_mut().enumerate() {
                *r = p[i] + cfg.noise * rng.normal();
            }
            for i in 0..d {
                let mut acc = 0.0;
                let row = &mix[i * d..(i + 1) * d];
                for (j, &m) in row.iter().enumerate() {
                    acc += m * raw[j];
                }
                out.push(acc.tanh() as f32);
            }
        };

        let gen_split = |n: usize, rng: &mut Rng| {
            let mut xs = Vec::with_capacity(n * d);
            let mut ys = Vec::with_capacity(n);
            for i in 0..n {
                let class = i % cfg.classes; // balanced overall
                ys.push(class as i32);
                sample(class, rng, &mut xs);
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen_split(cfg.n_train, &mut rng);
        let (test_x, test_y) = gen_split(cfg.n_test, &mut rng);
        SynthDataset {
            dim: d,
            classes: cfg.classes,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }

    pub fn train_len(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_len(&self) -> usize {
        self.test_y.len()
    }

    /// Borrow the feature row of train sample `i`.
    pub fn train_row(&self, i: usize) -> &[f32] {
        &self.train_x[i * self.dim..(i + 1) * self.dim]
    }

    pub fn test_row(&self, i: usize) -> &[f32] {
        &self.test_x[i * self.dim..(i + 1) * self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let cfg = SynthConfig::new(16, 4, 200, 40);
        let ds = SynthDataset::generate(&cfg);
        assert_eq!(ds.train_x.len(), 200 * 16);
        assert_eq!(ds.train_y.len(), 200);
        assert_eq!(ds.test_len(), 40);
        assert!(ds.train_x.iter().all(|&x| (-1.0..=1.0).contains(&x)));
        assert!(ds.train_y.iter().all(|&y| (0..4).contains(&y)));
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SynthConfig::new(8, 3, 50, 10);
        let a = SynthDataset::generate(&cfg);
        let b = SynthDataset::generate(&cfg);
        assert_eq!(a.train_x, b.train_x);
        let mut cfg2 = cfg.clone();
        cfg2.seed = 1;
        let c = SynthDataset::generate(&cfg2);
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn classes_are_separable_by_centroid() {
        // nearest-centroid classification on train data should beat chance
        // comfortably — the task must be learnable.
        let cfg = SynthConfig::new(32, 5, 500, 100);
        let ds = SynthDataset::generate(&cfg);
        let d = ds.dim;
        let mut centroids = vec![vec![0.0f64; d]; 5];
        let mut counts = vec![0usize; 5];
        for i in 0..ds.train_len() {
            let y = ds.train_y[i] as usize;
            counts[y] += 1;
            for (j, &x) in ds.train_row(i).iter().enumerate() {
                centroids[y][j] += x as f64;
            }
        }
        for (c, n) in centroids.iter_mut().zip(&counts) {
            for v in c.iter_mut() {
                *v /= *n as f64;
            }
        }
        let mut correct = 0;
        for i in 0..ds.test_len() {
            let row = ds.test_row(i);
            let best = (0..5)
                .min_by(|&a, &b| {
                    let da: f64 = row
                        .iter()
                        .zip(&centroids[a])
                        .map(|(&x, &c)| (x as f64 - c).powi(2))
                        .sum();
                    let db: f64 = row
                        .iter()
                        .zip(&centroids[b])
                        .map(|(&x, &c)| (x as f64 - c).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == ds.test_y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.test_len() as f64;
        assert!(acc > 0.5, "centroid acc {acc} (chance 0.2)");
    }
}
