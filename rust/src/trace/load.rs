//! Synthetic per-client utilisation traces (Alibaba `gpu_wrk_util`
//! substitute) and the coarse `gpu_plan`-style plan forecast.
//!
//! Structure preserved from the real trace family: a diurnal baseline
//! (clusters are busier in working hours), Markov-modulated job bursts
//! that saturate the device for tens of minutes to hours, and idle floors.
//! Spare capacity for FL is `m_c · (1 − util)`.

use crate::util::rng::Rng;

/// Parameters of one client's load process.
#[derive(Clone, Debug)]
pub struct LoadModel {
    /// mean baseline utilisation in off-hours, [0,1]
    pub base_util: f64,
    /// extra diurnal utilisation amplitude (peaks mid-day), [0,1]
    pub diurnal_amp: f64,
    /// probability per step of a burst starting
    pub burst_start_p: f64,
    /// probability per step of an active burst ending
    pub burst_end_p: f64,
    /// utilisation during a burst
    pub burst_util: f64,
    /// local-time offset in hours (aligns diurnal pattern with the site)
    pub utc_offset_h: f64,
}

impl LoadModel {
    /// Randomised heterogeneous model (mirrors the spread of the 100
    /// machines sampled from the Alibaba trace in the paper).
    pub fn sample(rng: &mut Rng, utc_offset_h: f64) -> LoadModel {
        LoadModel {
            base_util: rng.range_f64(0.05, 0.4),
            diurnal_amp: rng.range_f64(0.1, 0.45),
            // bursts last ~30-240 min, start a few times a day
            burst_start_p: rng.range_f64(0.001, 0.006),
            burst_end_p: rng.range_f64(0.008, 0.03),
            burst_util: rng.range_f64(0.7, 1.0),
            utc_offset_h,
        }
    }

    /// Generate `steps` utilisation samples at `step_minutes` resolution.
    pub fn generate(&self, steps: usize, step_minutes: f64, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(steps);
        let mut bursting = rng.bool(0.1);
        for i in 0..steps {
            let local_h =
                (i as f64 * step_minutes / 60.0 + self.utc_offset_h).rem_euclid(24.0);
            // diurnal hump centred on 14:00 local
            let diurnal = self.diurnal_amp
                * (std::f64::consts::PI * ((local_h - 8.0) / 12.0))
                    .sin()
                    .max(0.0);
            if bursting {
                if rng.bool(self.burst_end_p * step_minutes) {
                    bursting = false;
                }
            } else if rng.bool(self.burst_start_p * step_minutes) {
                bursting = true;
            }
            let mut util = self.base_util + diurnal + 0.03 * rng.normal();
            if bursting {
                util = util.max(self.burst_util + 0.05 * rng.normal());
            }
            out.push(util.clamp(0.0, 1.0));
        }
        out
    }
}

/// `gpu_plan`-style forecast: hourly-quantised smoothed utilisation. This
/// is what the paper's load forecasts look like — coarse but unbiased.
pub fn plan_forecast(actual: &[f64], step_minutes: f64) -> Vec<f64> {
    let per_hour = ((60.0 / step_minutes).round() as usize).max(1);
    let mut out = vec![0.0; actual.len()];
    let mut i = 0;
    while i < actual.len() {
        let end = (i + per_hour).min(actual.len());
        let mean: f64 =
            actual[i..end].iter().sum::<f64>() / (end - i) as f64;
        for o in out[i..end].iter_mut() {
            *o = mean;
        }
        i = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilisation_in_unit_interval() {
        let mut rng = Rng::new(1);
        let m = LoadModel::sample(&mut rng, 0.0);
        let trace = m.generate(10_000, 1.0, &mut rng);
        assert_eq!(trace.len(), 10_000);
        assert!(trace.iter().all(|&u| (0.0..=1.0).contains(&u)));
    }

    #[test]
    fn bursts_occur_and_end() {
        let mut rng = Rng::new(2);
        let m = LoadModel {
            base_util: 0.1,
            diurnal_amp: 0.0,
            burst_start_p: 0.01,
            burst_end_p: 0.02,
            burst_util: 0.95,
            utc_offset_h: 0.0,
        };
        let trace = m.generate(20_000, 1.0, &mut rng);
        let high = trace.iter().filter(|&&u| u > 0.85).count();
        assert!(high > 500, "no bursts? high={high}");
        assert!(high < 18_000, "never idle? high={high}");
    }

    #[test]
    fn diurnal_pattern_visible() {
        let mut rng = Rng::new(3);
        let m = LoadModel {
            base_util: 0.1,
            diurnal_amp: 0.4,
            burst_start_p: 0.0,
            burst_end_p: 1.0,
            burst_util: 0.0,
            utc_offset_h: 0.0,
        };
        // average over 10 days per minute-of-day
        let days = 10;
        let trace = m.generate(days * 1440, 1.0, &mut rng);
        let minute_mean = |min: usize| -> f64 {
            (0..days).map(|d| trace[d * 1440 + min]).sum::<f64>() / days as f64
        };
        assert!(minute_mean(14 * 60) > minute_mean(3 * 60) + 0.2);
    }

    #[test]
    fn plan_forecast_is_hourly_constant_and_unbiased() {
        let mut rng = Rng::new(4);
        let m = LoadModel::sample(&mut rng, 0.0);
        let trace = m.generate(1440, 1.0, &mut rng);
        let plan = plan_forecast(&trace, 1.0);
        // constant within each hour
        for h in 0..24 {
            let w = &plan[h * 60..(h + 1) * 60];
            assert!(w.iter().all(|&x| (x - w[0]).abs() < 1e-12));
        }
        // unbiased overall
        let ma: f64 = trace.iter().sum::<f64>() / trace.len() as f64;
        let mp: f64 = plan.iter().sum::<f64>() / plan.len() as f64;
        assert!((ma - mp).abs() < 1e-9);
    }
}
