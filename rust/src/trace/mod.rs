//! Trace substrate: synthetic stand-ins for the paper's external data
//! sources (documented in DESIGN.md §2).
//!
//! * [`solar`] — Solcast solar actuals → clear-sky irradiance model ×
//!   AR(1) cloud process per site (global + co-located city presets).
//! * [`load`] — Alibaba GPU-cluster utilisation (`gpu_wrk_util`) →
//!   diurnal baseline + Markov-modulated bursts per client, plus the
//!   coarse `gpu_plan`-style forecast.
//! * [`forecast`] — horizon-dependent error model layered over any actual
//!   series (solar forecasts in the paper come from Solcast with realistic
//!   error; Fig 7 sweeps error off/on).
//! * [`curtailment`] — CAISO-style quarterly curtailment series (Fig 1).

pub mod curtailment;
pub mod forecast;
pub mod load;
pub mod solar;
