//! Synthetic solar power traces with the structure that matters to the
//! scheduler: diurnal clear-sky shape (latitude + day-of-year), time-zone
//! phase offsets between sites, and an AR(1) cloud process that is shared
//! regionally in the co-located scenario and independent in the global
//! scenario (Fig 2/4 of the paper).

use crate::util::rng::Rng;

/// A solar site (one power domain's generation). Sites are either one of
/// the paper's presets ([`global_sites`], [`colocated_sites`]) or fully
/// parameterized custom entries built by the declarative scenario layer
/// (`crate::scenario`), hence the owned name.
#[derive(Clone, Debug)]
pub struct Site {
    pub name: String,
    /// latitude in degrees (drives day length + peak elevation)
    pub latitude: f64,
    /// offset of local solar noon from simulation time, in hours
    pub utc_offset_h: f64,
    /// cloudiness in [0, 1]: expected depth of cloud attenuation
    pub cloudiness: f64,
}

impl Site {
    pub fn new(name: &str, latitude: f64, utc_offset_h: f64, cloudiness: f64) -> Site {
        Site { name: name.to_string(), latitude, utc_offset_h, cloudiness }
    }
}

/// Ten globally distributed cities (paper: global scenario, June 8–15).
pub fn global_sites() -> Vec<Site> {
    vec![
        Site::new("Berlin", 52.5, 2.0, 0.35),
        Site::new("Lagos", 6.5, 1.0, 0.45),
        Site::new("Mumbai", 19.1, 5.5, 0.5),
        Site::new("Tokyo", 35.7, 9.0, 0.4),
        Site::new("Sydney", -33.9, 10.0, 0.3),
        Site::new("SaoPaulo", -23.6, -3.0, 0.35),
        Site::new("MexicoCity", 19.4, -6.0, 0.3),
        Site::new("SanFrancisco", 37.8, -7.0, 0.2),
        Site::new("NewYork", 40.7, -4.0, 0.35),
        Site::new("CapeTown", -33.9, 2.0, 0.25),
    ]
}

/// Ten largest German cities (paper: co-located scenario, July 15–22).
pub fn colocated_sites() -> Vec<Site> {
    let cities: [(&'static str, f64); 10] = [
        ("Berlin", 52.5),
        ("Hamburg", 53.6),
        ("Munich", 48.1),
        ("Cologne", 50.9),
        ("Frankfurt", 50.1),
        ("Stuttgart", 48.8),
        ("Duesseldorf", 51.2),
        ("Leipzig", 51.3),
        ("Dortmund", 51.5),
        ("Essen", 51.5),
    ];
    cities
        .iter()
        .map(|&(name, latitude)| Site::new(name, latitude, 2.0, 0.4))
        .collect()
}

/// Fraction of daylight-hours elevation for a given local solar hour.
/// Returns 0 at night; a sine hump between sunrise and sunset whose width
/// follows the standard solar-declination day-length model.
pub fn clear_sky_factor(latitude: f64, day_of_year: u32, local_hour: f64) -> f64 {
    let phi = latitude.to_radians();
    // solar declination (Cooper's formula)
    let decl = (23.44f64).to_radians()
        * (2.0 * std::f64::consts::PI * (284.0 + day_of_year as f64) / 365.0)
            .sin();
    // sunset hour angle; clamp handles polar day/night
    let cos_omega = (-phi.tan() * decl.tan()).clamp(-1.0, 1.0);
    let omega0 = cos_omega.acos(); // radians
    let day_len_h = 2.0 * omega0 * 12.0 / std::f64::consts::PI;
    if day_len_h <= 0.0 {
        return 0.0;
    }
    let sunrise = 12.0 - day_len_h / 2.0;
    let sunset = 12.0 + day_len_h / 2.0;
    let h = local_hour.rem_euclid(24.0);
    if h < sunrise || h > sunset {
        return 0.0;
    }
    // peak elevation factor: higher-latitude summer noon sun is lower
    let noon_elev = (phi - decl).cos().max(0.0);
    let shape = (std::f64::consts::PI * (h - sunrise) / day_len_h).sin();
    (noon_elev * shape).max(0.0)
}

/// Generate a power trace (W) for one site.
///
/// `regional_clouds`: optional shared cloud series (same length) for the
/// co-located scenario; the site mixes it with local AR(1) noise.
pub fn generate(
    site: &Site,
    capacity_w: f64,
    start_day_of_year: u32,
    steps: usize,
    step_minutes: f64,
    rng: &mut Rng,
    regional_clouds: Option<&[f64]>,
) -> Vec<f64> {
    let mut out = Vec::with_capacity(steps);
    let mut cloud = rng.f64() * site.cloudiness;
    // AR(1) with ~3 h correlation time at 1-min steps
    let alpha = (-step_minutes / 180.0f64).exp();
    let noise_std = site.cloudiness * (1.0 - alpha * alpha).sqrt();
    for i in 0..steps {
        let sim_hour = i as f64 * step_minutes / 60.0;
        let local_hour = sim_hour + site.utc_offset_h;
        let day = start_day_of_year + (local_hour / 24.0).floor() as u32;
        let cs = clear_sky_factor(site.latitude, day, local_hour);
        cloud = alpha * cloud
            + (1.0 - alpha) * site.cloudiness * 0.8
            + noise_std * rng.normal() * 0.5;
        cloud = cloud.clamp(0.0, 1.0);
        let effective_cloud = match regional_clouds {
            Some(reg) => (0.7 * reg[i] + 0.3 * cloud).clamp(0.0, 1.0),
            None => cloud,
        };
        out.push(capacity_w * cs * (1.0 - effective_cloud));
    }
    out
}

/// Shared regional cloud series for co-located sites.
pub fn regional_cloud_series(
    steps: usize,
    step_minutes: f64,
    cloudiness: f64,
    rng: &mut Rng,
) -> Vec<f64> {
    let alpha = (-step_minutes / 240.0f64).exp();
    let noise_std = cloudiness * (1.0 - alpha * alpha).sqrt();
    let mut cloud = rng.f64() * cloudiness;
    (0..steps)
        .map(|_| {
            cloud = alpha * cloud
                + (1.0 - alpha) * cloudiness
                + noise_std * rng.normal() * 0.6;
            cloud = cloud.clamp(0.0, 1.0);
            cloud
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn night_is_dark() {
        // local midnight
        assert_eq!(clear_sky_factor(52.5, 170, 0.0), 0.0);
        assert_eq!(clear_sky_factor(52.5, 170, 23.0), 0.0);
    }

    #[test]
    fn noon_is_bright_and_peak() {
        let noon = clear_sky_factor(52.5, 170, 12.0);
        assert!(noon > 0.5, "noon={noon}");
        assert!(noon >= clear_sky_factor(52.5, 170, 9.0));
        assert!(noon >= clear_sky_factor(52.5, 170, 15.0));
    }

    #[test]
    fn southern_hemisphere_winter_days_are_short() {
        // June (day 170): Sydney winter vs Berlin summer
        let count_daylight = |lat: f64| {
            (0..24 * 60)
                .filter(|&m| clear_sky_factor(lat, 170, m as f64 / 60.0) > 0.0)
                .count()
        };
        assert!(count_daylight(-33.9) < count_daylight(52.5));
    }

    #[test]
    fn trace_is_nonnegative_and_bounded() {
        let mut rng = Rng::new(1);
        let site = &global_sites()[0];
        let trace = generate(site, 800.0, 160, 7 * 24 * 60, 1.0, &mut rng, None);
        assert_eq!(trace.len(), 7 * 24 * 60);
        assert!(trace.iter().all(|&p| (0.0..=800.0).contains(&p)));
        // some sun must appear over a week
        assert!(trace.iter().cloned().fold(0.0, f64::max) > 100.0);
    }

    #[test]
    fn global_sites_are_phase_shifted() {
        // Tokyo and San Francisco peaks should be far apart in sim time
        let mut rng = Rng::new(2);
        let sites = global_sites();
        let tokyo = sites.iter().find(|s| s.name == "Tokyo").unwrap();
        let sf = sites.iter().find(|s| s.name == "SanFrancisco").unwrap();
        let day = 24 * 60;
        let t1 = generate(tokyo, 800.0, 160, day, 1.0, &mut rng, None);
        let t2 = generate(sf, 800.0, 160, day, 1.0, &mut rng, None);
        // centre of mass of production is robust to cloud noise
        let com = |v: &[f64]| {
            let total: f64 = v.iter().sum();
            v.iter().enumerate().map(|(i, &p)| i as f64 * p).sum::<f64>() / total
        };
        let gap_h = (com(&t1) - com(&t2)).abs() / 60.0;
        let gap_h = gap_h.min(24.0 - gap_h);
        assert!(gap_h > 5.0, "gap {gap_h} h");
    }

    #[test]
    fn colocated_sites_are_synchronized() {
        let mut rng = Rng::new(3);
        let sites = colocated_sites();
        let day = 24 * 60;
        let reg = regional_cloud_series(day, 1.0, 0.4, &mut rng);
        let traces: Vec<Vec<f64>> = sites
            .iter()
            .map(|s| generate(s, 800.0, 196, day, 1.0, &mut rng, Some(&reg)))
            .collect();
        // every pair of sites should have daylight at the same steps
        let sunny = |v: &[f64]| -> Vec<bool> { v.iter().map(|&p| p > 1.0).collect() };
        let a = sunny(&traces[0]);
        for t in &traces[1..] {
            let b = sunny(t);
            let agree = a.iter().zip(&b).filter(|(x, y)| x == y).count();
            assert!(agree as f64 / a.len() as f64 > 0.9);
        }
    }
}
