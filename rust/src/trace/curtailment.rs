//! CAISO-style curtailment series (the paper's Fig 1 motivation chart).
//!
//! The figure shows quarterly wind+solar curtailment in GWh, growing
//! year-over-year with a strong spring peak (high solar + mild demand +
//! hydro runoff). We model exactly that: exponential annual growth × a
//! seasonal profile, with deterministic jitter — calibrated so 2022 totals
//! land near the ~2.4 TWh the paper cites (≈7% of CAISO solar).

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct QuarterRecord {
    pub year: u32,
    pub quarter: u8,
    pub curtailment_gwh: f64,
}

/// Seasonal multipliers (Q1..Q4): spring-heavy, as in CAISO reports.
const SEASON: [f64; 4] = [1.1, 1.9, 0.6, 0.4];

pub fn caiso_series(from_year: u32, to_year: u32, seed: u64) -> Vec<QuarterRecord> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for year in from_year..=to_year {
        // 2015 baseline ~ 47 GWh/quarter avg, ~35%/yr growth hits
        // ~600 GWh/quarter avg by 2022 (≈2.4 TWh/yr)
        let annual = 187.0 * 1.38f64.powi(year as i32 - 2015);
        for quarter in 1..=4u8 {
            let jitter = 1.0 + 0.12 * rng.normal();
            let gwh =
                (annual / 4.0 * SEASON[quarter as usize - 1] * 4.0 * jitter / 4.0)
                    .max(0.0);
            out.push(QuarterRecord { year, quarter, curtailment_gwh: gwh });
        }
    }
    out
}

/// Annual total in TWh.
pub fn annual_twh(series: &[QuarterRecord], year: u32) -> f64 {
    series
        .iter()
        .filter(|r| r.year == year)
        .map(|r| r.curtailment_gwh)
        .sum::<f64>()
        / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_year_over_year() {
        let s = caiso_series(2015, 2024, 1);
        for y in 2016..=2024 {
            assert!(
                annual_twh(&s, y) > annual_twh(&s, y - 1) * 0.95,
                "year {y} did not grow"
            );
        }
    }

    #[test]
    fn spring_peak() {
        let s = caiso_series(2015, 2024, 1);
        let q = |year: u32, quarter: u8| {
            s.iter()
                .find(|r| r.year == year && r.quarter == quarter)
                .unwrap()
                .curtailment_gwh
        };
        for year in [2018, 2021, 2024] {
            assert!(q(year, 2) > q(year, 3));
            assert!(q(year, 2) > q(year, 4));
        }
    }

    #[test]
    fn calibrated_to_paper_2022_magnitude() {
        let s = caiso_series(2015, 2024, 1);
        let t2022 = annual_twh(&s, 2022);
        // paper: >2.4 TWh utility-scale solar curtailed in 2022
        assert!((1.5..4.5).contains(&t2022), "2022 total {t2022} TWh");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = caiso_series(2015, 2020, 9);
        let b = caiso_series(2015, 2020, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.curtailment_gwh, y.curtailment_gwh);
        }
    }
}
