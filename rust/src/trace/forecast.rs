//! Horizon-dependent forecast error model.
//!
//! The paper uses Solcast's real forecasts, whose error grows with lead
//! time; Fig 7 compares FedZero with realistic errors, perfect forecasts,
//! and missing load forecasts. We reproduce that axis with a deterministic
//! error field: for issue time `t0` and target step `t`, the forecast is
//!
//!   f(t0, t) = max(0, actual[t] · (1 + bias + σ(h)·ε(t0, t)))
//!
//! where h = t − t0, σ(h) = σ0·sqrt(h/h0) saturating at σ_max, and ε is a
//! unit-variance hash-noise — deterministic in (seed, t0, t) so repeated
//! queries are consistent within a round.
//!
//! Because the error depends on the issue time `t0`, consumers that cache
//! forecast windows must fix an **anchor**: the persistent ring-arena
//! (`selection::ring`) keeps the `t0` it was built with across
//! incremental advances and re-anchors (re-issues) at round boundaries —
//! the simulated server queries forecasts at round start, not every
//! polled minute. `forecast(t0, t)` must stay pure in `(t0, t)` for that
//! caching to be sound (guarded by `forecast_is_deterministic_per_issue_time`).

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorLevel {
    /// perfect foresight (Fig 7 "w/o error")
    Perfect,
    /// realistic, horizon-growing error (default)
    Realistic,
    /// no forecast available at all — callers substitute a static
    /// assumption (Fig 7 "no load forecast": spare = full capacity)
    Unavailable,
}

#[derive(Clone, Debug)]
pub struct SeriesForecaster {
    pub actual: Vec<f64>,
    pub level: ErrorLevel,
    /// relative error std at 1 h lead
    pub sigma0: f64,
    /// saturation of the relative error
    pub sigma_max: f64,
    /// multiplicative bias (systematic over/under-forecasting)
    pub bias: f64,
    pub seed: u64,
    /// steps per hour (error growth is calibrated in hours)
    pub steps_per_hour: f64,
}

impl SeriesForecaster {
    pub fn realistic(actual: Vec<f64>, seed: u64, steps_per_hour: f64) -> Self {
        SeriesForecaster {
            actual,
            level: ErrorLevel::Realistic,
            sigma0: 0.10,
            sigma_max: 0.35,
            bias: 0.02,
            seed,
            steps_per_hour,
        }
    }

    pub fn perfect(actual: Vec<f64>) -> Self {
        SeriesForecaster {
            actual,
            level: ErrorLevel::Perfect,
            sigma0: 0.0,
            sigma_max: 0.0,
            bias: 0.0,
            seed: 0,
            steps_per_hour: 60.0,
        }
    }

    pub fn len(&self) -> usize {
        self.actual.len()
    }

    pub fn is_empty(&self) -> bool {
        self.actual.is_empty()
    }

    pub fn actual_at(&self, t: usize) -> f64 {
        self.actual.get(t).copied().unwrap_or(0.0)
    }

    /// Forecast issued at `t0` for absolute step `t >= t0`.
    pub fn forecast(&self, t0: usize, t: usize) -> f64 {
        debug_assert!(t >= t0);
        let a = self.actual_at(t);
        match self.level {
            ErrorLevel::Perfect => a,
            ErrorLevel::Unavailable => 0.0,
            ErrorLevel::Realistic => {
                let h_hours = (t - t0) as f64 / self.steps_per_hour;
                let sigma =
                    (self.sigma0 * h_hours.sqrt()).min(self.sigma_max);
                let eps = hash_normal(self.seed, t0 as u64, t as u64);
                (a * (1.0 + self.bias + sigma * eps)).max(0.0)
            }
        }
    }

    /// Forecast the whole window [t0, t0+horizon).
    pub fn forecast_window(&self, t0: usize, horizon: usize) -> Vec<f64> {
        (t0..t0 + horizon).map(|t| self.forecast(t0, t)).collect()
    }
}

/// Deterministic standard-normal noise from a (seed, a, b) triple.
fn hash_normal(seed: u64, a: u64, b: u64) -> f64 {
    let mixed = seed
        ^ a.wrapping_mul(0x9E3779B97F4A7C15)
        ^ b.wrapping_mul(0xC2B2AE3D27D4EB4F);
    Rng::new(mixed).normal()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f64> {
        (0..n).map(|i| 100.0 + i as f64).collect()
    }

    #[test]
    fn perfect_is_exact() {
        let f = SeriesForecaster::perfect(ramp(100));
        for t0 in [0usize, 10, 50] {
            for h in [0usize, 1, 30] {
                assert_eq!(f.forecast(t0, t0 + h), 100.0 + (t0 + h) as f64);
            }
        }
    }

    #[test]
    fn realistic_error_grows_with_horizon() {
        let n = 2000;
        let f = SeriesForecaster::realistic(vec![100.0; n], 7, 60.0);
        let rel_err = |h: usize| -> f64 {
            let mut s = 0.0;
            let mut cnt = 0;
            for t0 in (0..n - h).step_by(13) {
                s += (f.forecast(t0, t0 + h) - 100.0).abs() / 100.0;
                cnt += 1;
            }
            s / cnt as f64
        };
        let short = rel_err(5);
        let long = rel_err(600);
        assert!(long > short * 1.5, "short={short} long={long}");
    }

    #[test]
    fn forecast_is_deterministic_per_issue_time() {
        let f = SeriesForecaster::realistic(ramp(100), 9, 60.0);
        assert_eq!(f.forecast(3, 40), f.forecast(3, 40));
        // different issue times give different errors
        let a = f.forecast(3, 40);
        let b = f.forecast(4, 40);
        assert_ne!(a, b);
    }

    #[test]
    fn never_negative() {
        let f = SeriesForecaster::realistic(vec![0.5; 500], 11, 60.0);
        for t0 in 0..400 {
            assert!(f.forecast(t0, t0 + 60) >= 0.0);
        }
    }

    #[test]
    fn window_matches_pointwise() {
        let f = SeriesForecaster::realistic(ramp(50), 13, 60.0);
        let w = f.forecast_window(5, 10);
        for (k, &v) in w.iter().enumerate() {
            assert_eq!(v, f.forecast(5, 5 + k));
        }
    }

    #[test]
    fn out_of_range_is_zero() {
        let f = SeriesForecaster::perfect(ramp(10));
        assert_eq!(f.forecast(5, 50), 0.0);
    }
}
