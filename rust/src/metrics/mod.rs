//! Experiment metrics: everything the paper reports.
//!
//! Round records + periodic evaluations roll up into the paper's headline
//! numbers: best accuracy, time-to-accuracy (days), energy-to-accuracy
//! (kWh) [Table 3], round-duration statistics (§5.2), and per-client /
//! per-domain participation shares (Fig 6).

use crate::util::json::{arr, num, obj, Json};
use crate::util::stats;

#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    pub start_step: usize,
    pub duration_steps: usize,
    pub selected: Vec<usize>,
    pub participants: Vec<usize>,
    pub batches: f64,
    pub energy_wh: f64,
    /// energy metered to clients whose round work was discarded
    /// (stragglers that missed m_min) — the waste column of the
    /// campaign report
    pub wasted_wh: f64,
    pub mean_loss: f64,
    /// the round closed on its deadline/horizon with fewer than
    /// `n_required` submitted updates (instead of on its quorum)
    pub timed_out: bool,
    /// distinct energy domains among the participants — the domain
    /// shards the hierarchical aggregator reduced (0 when the round
    /// produced no participants). A pure function of `participants`,
    /// identical under flat and tree aggregation.
    pub agg_domains: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EvalRecord {
    pub round: usize,
    pub step: usize,
    pub accuracy: f64,
    pub loss: f64,
    /// cumulative energy at eval time, kWh
    pub cumulative_kwh: f64,
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsLog {
    pub rounds: Vec<RoundRecord>,
    pub evals: Vec<EvalRecord>,
    pub step_minutes: f64,
    /// updates rejected for carrying a stale epoch token (arrived
    /// after their round closed) — metered, never aggregated
    pub rejected_updates: usize,
    /// malformed `SelectionDecision`s rejected at the FSM boundary
    /// (duplicate / out-of-range clients)
    pub rejected_decisions: usize,
}

impl MetricsLog {
    pub fn new(step_minutes: f64) -> Self {
        MetricsLog {
            rounds: Vec::new(),
            evals: Vec::new(),
            step_minutes,
            rejected_updates: 0,
            rejected_decisions: 0,
        }
    }

    pub fn best_accuracy(&self) -> f64 {
        self.evals.iter().map(|e| e.accuracy).fold(0.0, f64::max)
    }

    pub fn final_accuracy(&self) -> f64 {
        self.evals.last().map(|e| e.accuracy).unwrap_or(0.0)
    }

    fn step_to_days(&self, step: usize) -> f64 {
        step as f64 * self.step_minutes / 60.0 / 24.0
    }

    /// First eval index that SUSTAINS `target` accuracy: the eval and its
    /// successor are both >= target (a single-point crossing of a noisy
    /// eval curve is not "reached"); the last eval counts alone.
    fn sustained_index(&self, target: f64) -> Option<usize> {
        (0..self.evals.len()).find(|&i| {
            self.evals[i].accuracy >= target
                && self
                    .evals
                    .get(i + 1)
                    .map(|n| n.accuracy >= target)
                    .unwrap_or(true)
        })
    }

    /// first sim-time (days) at which evals sustainably reach `target`
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.sustained_index(target)
            .map(|i| self.step_to_days(self.evals[i].step))
    }

    /// energy (kWh) consumed up to sustainably reaching `target` accuracy
    pub fn energy_to_accuracy(&self, target: f64) -> Option<f64> {
        self.sustained_index(target)
            .map(|i| self.evals[i].cumulative_kwh)
    }

    pub fn total_energy_kwh(&self) -> f64 {
        self.rounds.iter().map(|r| r.energy_wh).sum::<f64>() / 1000.0
    }

    /// energy spent on work that was discarded (straggler updates)
    pub fn total_wasted_kwh(&self) -> f64 {
        self.rounds.iter().map(|r| r.wasted_wh).sum::<f64>() / 1000.0
    }

    pub fn round_durations_min(&self) -> Vec<f64> {
        self.rounds
            .iter()
            .map(|r| r.duration_steps as f64 * self.step_minutes)
            .collect()
    }

    pub fn mean_round_duration_min(&self) -> f64 {
        stats::mean(&self.round_durations_min())
    }

    /// rounds that closed on their deadline/horizon instead of their
    /// quorum (the Semi-Sync / chaos robustness column)
    pub fn timeout_rounds(&self) -> usize {
        self.rounds.iter().filter(|r| r.timed_out).count()
    }

    /// participation count per client id (who completed m_min)
    pub fn participation_counts(&self, n_clients: usize) -> Vec<usize> {
        let mut counts = vec![0usize; n_clients];
        for r in &self.rounds {
            for &c in &r.participants {
                counts[c] += 1;
            }
        }
        counts
    }

    /// fraction of rounds each client participated in
    pub fn participation_shares(&self, n_clients: usize) -> Vec<f64> {
        let total = self.rounds.len().max(1) as f64;
        self.participation_counts(n_clients)
            .into_iter()
            .map(|c| c as f64 / total)
            .collect()
    }

    /// mean ± std of participation share per power domain (Fig 6):
    /// returns (mean_share, within_domain_std) per domain plus the
    /// between-domain std of the means.
    pub fn participation_by_domain(
        &self,
        client_domains: &[usize],
        n_domains: usize,
    ) -> (Vec<(f64, f64)>, f64) {
        let shares = self.participation_shares(client_domains.len());
        let mut per_domain: Vec<Vec<f64>> = vec![Vec::new(); n_domains];
        for (c, &d) in client_domains.iter().enumerate() {
            per_domain[d].push(shares[c]);
        }
        let summaries: Vec<(f64, f64)> = per_domain
            .iter()
            .map(|v| (stats::mean(v), stats::std(v)))
            .collect();
        let means: Vec<f64> = summaries.iter().map(|&(m, _)| m).collect();
        (summaries, stats::std(&means))
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("step_minutes", num(self.step_minutes)),
            ("best_accuracy", num(self.best_accuracy())),
            ("total_energy_kwh", num(self.total_energy_kwh())),
            ("rejected_updates", num(self.rejected_updates as f64)),
            ("rejected_decisions", num(self.rejected_decisions as f64)),
            ("timeout_rounds", num(self.timeout_rounds() as f64)),
            (
                "rounds",
                arr(self
                    .rounds
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("round", num(r.round as f64)),
                            ("start_step", num(r.start_step as f64)),
                            ("duration", num(r.duration_steps as f64)),
                            ("participants", num(r.participants.len() as f64)),
                            ("batches", num(r.batches)),
                            ("energy_wh", num(r.energy_wh)),
                            ("wasted_wh", num(r.wasted_wh)),
                            ("mean_loss", num(r.mean_loss)),
                            ("timed_out", Json::Bool(r.timed_out)),
                            ("agg_domains", num(r.agg_domains as f64)),
                        ])
                    })
                    .collect()),
            ),
            (
                "evals",
                arr(self
                    .evals
                    .iter()
                    .map(|e| {
                        obj(vec![
                            ("round", num(e.round as f64)),
                            ("step", num(e.step as f64)),
                            ("accuracy", num(e.accuracy)),
                            ("loss", num(e.loss)),
                            ("kwh", num(e.cumulative_kwh)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    /// Lossless checkpoint codec — unlike [`MetricsLog::to_json`] (a
    /// report format that drops the per-round `selected`/`participants`
    /// id lists), this serialises every field so a resumed run rebuilds
    /// a `MetricsLog` that compares equal (`PartialEq`, f64 bits
    /// included: the JSON writer prints shortest-roundtrip doubles).
    pub fn snapshot_json(&self) -> Json {
        let usize_arr =
            |v: &[usize]| Json::Arr(v.iter().map(|&x| num(x as f64)).collect());
        obj(vec![
            ("step_minutes", num(self.step_minutes)),
            ("rejected_updates", num(self.rejected_updates as f64)),
            ("rejected_decisions", num(self.rejected_decisions as f64)),
            (
                "rounds",
                arr(self
                    .rounds
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("round", num(r.round as f64)),
                            ("start_step", num(r.start_step as f64)),
                            ("duration_steps", num(r.duration_steps as f64)),
                            ("selected", usize_arr(&r.selected)),
                            ("participants", usize_arr(&r.participants)),
                            ("batches", num(r.batches)),
                            ("energy_wh", num(r.energy_wh)),
                            ("wasted_wh", num(r.wasted_wh)),
                            ("mean_loss", num(r.mean_loss)),
                            ("timed_out", Json::Bool(r.timed_out)),
                            ("agg_domains", num(r.agg_domains as f64)),
                        ])
                    })
                    .collect()),
            ),
            (
                "evals",
                arr(self
                    .evals
                    .iter()
                    .map(|e| {
                        obj(vec![
                            ("round", num(e.round as f64)),
                            ("step", num(e.step as f64)),
                            ("accuracy", num(e.accuracy)),
                            ("loss", num(e.loss)),
                            ("cumulative_kwh", num(e.cumulative_kwh)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    /// Parse a [`MetricsLog::snapshot_json`] document.
    pub fn from_snapshot_json(j: &Json) -> Result<MetricsLog, String> {
        let f = |j: &Json, k: &str| -> Result<f64, String> {
            j.get(k).and_then(|v| v.as_f64()).ok_or_else(|| format!("missing {k}"))
        };
        let u = |j: &Json, k: &str| -> Result<usize, String> {
            j.get(k).and_then(|v| v.as_usize()).ok_or_else(|| format!("missing {k}"))
        };
        let b = |j: &Json, k: &str| -> Result<bool, String> {
            j.get(k).and_then(|v| v.as_bool()).ok_or_else(|| format!("missing {k}"))
        };
        let ids = |j: &Json, k: &str| -> Result<Vec<usize>, String> {
            j.get(k)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| format!("missing {k}"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| format!("bad id in {k}")))
                .collect()
        };
        let mut log = MetricsLog::new(f(j, "step_minutes")?);
        log.rejected_updates = u(j, "rejected_updates")?;
        log.rejected_decisions = u(j, "rejected_decisions")?;
        for r in j.get("rounds").and_then(|v| v.as_arr()).ok_or("missing rounds")? {
            log.rounds.push(RoundRecord {
                round: u(r, "round")?,
                start_step: u(r, "start_step")?,
                duration_steps: u(r, "duration_steps")?,
                selected: ids(r, "selected")?,
                participants: ids(r, "participants")?,
                batches: f(r, "batches")?,
                energy_wh: f(r, "energy_wh")?,
                wasted_wh: f(r, "wasted_wh")?,
                mean_loss: f(r, "mean_loss")?,
                timed_out: b(r, "timed_out")?,
                agg_domains: u(r, "agg_domains")?,
            });
        }
        for e in j.get("evals").and_then(|v| v.as_arr()).ok_or("missing evals")? {
            log.evals.push(EvalRecord {
                round: u(e, "round")?,
                step: u(e, "step")?,
                accuracy: f(e, "accuracy")?,
                loss: f(e, "loss")?,
                cumulative_kwh: f(e, "cumulative_kwh")?,
            });
        }
        Ok(log)
    }

    /// one-line human summary
    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name:<14} rounds {:>4}  best acc {:>6.2}%  energy {:>7.2} kWh  mean round {:>5.1} min",
            self.rounds.len(),
            self.best_accuracy() * 100.0,
            self.total_energy_kwh(),
            self.mean_round_duration_min(),
        )
    }

    /// write a JSON report next to stdout prints
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    #[allow(clippy::type_complexity)]
    pub fn dummy_for_tests() -> Self {
        let mut m = MetricsLog::new(1.0);
        for round in 0..4 {
            m.rounds.push(RoundRecord {
                round,
                start_step: round * 20,
                duration_steps: 10,
                selected: vec![0, 1],
                participants: vec![round % 2],
                batches: 50.0,
                energy_wh: 500.0,
                wasted_wh: 60.0,
                mean_loss: 1.0,
                timed_out: round == 3,
                agg_domains: 1,
            });
            m.evals.push(EvalRecord {
                round,
                step: round * 20 + 10,
                accuracy: 0.2 + 0.1 * round as f64,
                loss: 2.0 - 0.2 * round as f64,
                cumulative_kwh: 0.5 * (round + 1) as f64,
            });
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_and_energy_to_target() {
        let m = MetricsLog::dummy_for_tests();
        assert!((m.best_accuracy() - 0.5).abs() < 1e-12);
        // target 0.4 first reached at round 2, step 50
        let days = m.time_to_accuracy(0.4).unwrap();
        assert!((days - 50.0 / 60.0 / 24.0).abs() < 1e-9);
        assert!((m.energy_to_accuracy(0.4).unwrap() - 1.5).abs() < 1e-12);
        assert!(m.time_to_accuracy(0.99).is_none());
        assert!((m.total_energy_kwh() - 2.0).abs() < 1e-12);
        assert!((m.total_wasted_kwh() - 0.24).abs() < 1e-12);
    }

    #[test]
    fn participation_accounting() {
        let m = MetricsLog::dummy_for_tests();
        let counts = m.participation_counts(3);
        assert_eq!(counts, vec![2, 2, 0]);
        let shares = m.participation_shares(3);
        assert!((shares[0] - 0.5).abs() < 1e-12);
        let (per_domain, between) =
            m.participation_by_domain(&[0, 0, 1], 2);
        assert!((per_domain[0].0 - 0.5).abs() < 1e-12);
        assert_eq!(per_domain[1].0, 0.0);
        assert!(between > 0.0);
    }

    #[test]
    fn json_roundtrips() {
        let m = MetricsLog::dummy_for_tests();
        let j = m.to_json();
        let text = j.to_string_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("best_accuracy").unwrap().as_f64().unwrap(),
            0.5
        );
        assert_eq!(parsed.get("rounds").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn snapshot_codec_roundtrips_losslessly() {
        let mut m = MetricsLog::dummy_for_tests();
        m.rejected_updates = 5;
        m.rejected_decisions = 2;
        // adversarial f64s: shortest-roundtrip printing must survive
        m.rounds[1].energy_wh = 0.1 + 0.2;
        m.rounds[1].mean_loss = f64::MIN_POSITIVE;
        m.evals[0].accuracy = 1.0 / 3.0;
        let text = m.snapshot_json().to_string_pretty();
        let parsed = crate::util::json::Json::parse(&text).unwrap();
        let restored = MetricsLog::from_snapshot_json(&parsed).unwrap();
        assert_eq!(restored, m, "snapshot codec must be lossless");
        // unlike to_json, the id lists survive
        assert_eq!(restored.rounds[0].selected, vec![0, 1]);
        assert_eq!(restored.participation_counts(3), m.participation_counts(3));
    }

    #[test]
    fn sustained_crossing_ignores_single_spikes() {
        let mut m = MetricsLog::new(1.0);
        // acc: 0.1, 0.9 (spike), 0.2, 0.9, 0.9 -> target 0.8 sustained at
        // the 4th eval (index 3), not the spike at index 1
        for (i, acc) in [0.1, 0.9, 0.2, 0.9, 0.9].iter().enumerate() {
            m.evals.push(EvalRecord {
                round: i,
                step: (i + 1) * 10,
                accuracy: *acc,
                loss: 1.0,
                cumulative_kwh: (i + 1) as f64,
            });
        }
        let days = m.time_to_accuracy(0.8).unwrap();
        assert!((days - 40.0 / 1440.0).abs() < 1e-9, "days={days}");
        assert!((m.energy_to_accuracy(0.8).unwrap() - 4.0).abs() < 1e-12);
        // final eval counts alone (no successor required)
        let mut m2 = MetricsLog::new(1.0);
        m2.evals.push(EvalRecord {
            round: 0,
            step: 10,
            accuracy: 0.95,
            loss: 0.1,
            cumulative_kwh: 1.0,
        });
        assert!(m2.time_to_accuracy(0.9).is_some());
    }

    #[test]
    fn durations() {
        let m = MetricsLog::dummy_for_tests();
        assert!((m.mean_round_duration_min() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn robustness_counters_roundtrip() {
        let mut m = MetricsLog::dummy_for_tests();
        m.rejected_updates = 3;
        m.rejected_decisions = 1;
        assert_eq!(m.timeout_rounds(), 1, "dummy marks round 3 timed out");
        let parsed =
            crate::util::json::Json::parse(&m.to_json().to_string_pretty()).unwrap();
        assert_eq!(parsed.get("rejected_updates").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("rejected_decisions").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("timeout_rounds").unwrap().as_usize(), Some(1));
        let rounds = parsed.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds[0].get("timed_out").unwrap().as_bool(), Some(false));
        assert_eq!(rounds[3].get("timed_out").unwrap().as_bool(), Some(true));
    }
}
