//! Min-cost max-flow with f64 capacities/costs.
//!
//! Successive shortest paths with SPFA (Bellman–Ford queue) path search —
//! residual arcs carry negative costs, so Dijkstra-with-potentials would
//! need a Bellman–Ford initialisation anyway and the allocation graphs are
//! small (≤ a few thousand arcs). Starting from zero flow and always
//! augmenting along a cheapest path maintains the classic invariant that
//! the current flow is min-cost among flows of equal value, which is what
//! [`super::alloc`] relies on.

pub const EPS: f64 = 1e-9;

#[derive(Clone, Debug)]
struct Arc {
    to: usize,
    cap: f64,
    cost: f64,
    /// index of the reverse arc in `arcs`
    rev: usize,
}

#[derive(Clone, Debug, Default)]
pub struct FlowNetwork {
    arcs: Vec<Arc>,
    adj: Vec<Vec<usize>>,
    // SPFA scratch, reused across augmentations and across `reset()` cycles
    // so steady-state solves allocate nothing (§Perf: the selection hot
    // path runs thousands of small flow solves per round).
    dist: Vec<f64>,
    in_queue: Vec<bool>,
    pred: Vec<usize>,
    queue: std::collections::VecDeque<usize>,
}

impl FlowNetwork {
    pub fn new(nodes: usize) -> Self {
        FlowNetwork {
            arcs: Vec::new(),
            adj: vec![Vec::new(); nodes],
            dist: Vec::new(),
            in_queue: Vec::new(),
            pred: Vec::new(),
            queue: std::collections::VecDeque::new(),
        }
    }

    /// Clear the graph for reuse with `nodes` nodes, keeping every buffer's
    /// capacity. Equivalent to `*self = FlowNetwork::new(nodes)` without
    /// the allocations.
    pub fn reset(&mut self, nodes: usize) {
        self.arcs.clear();
        self.adj.truncate(nodes);
        for a in &mut self.adj {
            a.clear();
        }
        while self.adj.len() < nodes {
            self.adj.push(Vec::new());
        }
    }

    pub fn add_node(&mut self) -> usize {
        self.adj.push(Vec::new());
        self.adj.len() - 1
    }

    pub fn num_nodes(&self) -> usize {
        self.adj.len()
    }

    /// Add a directed arc; returns its id (for `flow_on`).
    pub fn add_edge(&mut self, from: usize, to: usize, cap: f64, cost: f64) -> usize {
        assert!(cap >= -EPS, "negative capacity {cap}");
        let id = self.arcs.len();
        self.arcs.push(Arc { to, cap: cap.max(0.0), cost, rev: id + 1 });
        self.adj[from].push(id);
        self.arcs.push(Arc { to: from, cap: 0.0, cost: -cost, rev: id });
        self.adj[to].push(id + 1);
        id
    }

    /// Flow currently on arc `id` (= residual capacity of its reverse arc).
    pub fn flow_on(&self, id: usize) -> f64 {
        self.arcs[self.arcs[id].rev].cap
    }

    /// Cheapest augmenting path via SPFA into the internal `pred` scratch;
    /// returns whether `t` is reachable.
    fn spfa(&mut self, s: usize, t: usize) -> bool {
        let n = self.num_nodes();
        self.dist.clear();
        self.dist.resize(n, f64::INFINITY);
        self.in_queue.clear();
        self.in_queue.resize(n, false);
        self.pred.clear();
        self.pred.resize(n, usize::MAX);
        self.queue.clear();
        self.dist[s] = 0.0;
        self.queue.push_back(s);
        self.in_queue[s] = true;
        while let Some(u) = self.queue.pop_front() {
            self.in_queue[u] = false;
            for &aid in &self.adj[u] {
                let arc = &self.arcs[aid];
                if arc.cap > EPS && self.dist[u] + arc.cost < self.dist[arc.to] - EPS {
                    self.dist[arc.to] = self.dist[u] + arc.cost;
                    self.pred[arc.to] = aid;
                    if !self.in_queue[arc.to] {
                        self.queue.push_back(arc.to);
                        self.in_queue[arc.to] = true;
                    }
                }
            }
        }
        self.dist[t].is_finite()
    }

    /// Min-cost max-flow from `s` to `t`, augmenting at most `limit` units.
    /// Returns (flow, cost). Set `limit = f64::INFINITY` for full max-flow.
    pub fn min_cost_max_flow(&mut self, s: usize, t: usize, limit: f64) -> (f64, f64) {
        let mut flow = 0.0;
        let mut cost = 0.0;
        while flow < limit - EPS {
            if !self.spfa(s, t) {
                break;
            }
            // bottleneck along path
            let mut push = limit - flow;
            let mut v = t;
            while v != s {
                let aid = self.pred[v];
                push = push.min(self.arcs[aid].cap);
                v = self.arcs[self.arcs[aid].rev].to;
            }
            if push <= EPS {
                break;
            }
            let mut v = t;
            while v != s {
                let aid = self.pred[v];
                let rev = self.arcs[aid].rev;
                self.arcs[aid].cap -= push;
                self.arcs[rev].cap += push;
                cost += push * self.arcs[aid].cost;
                v = self.arcs[rev].to;
            }
            flow += push;
        }
        (flow, cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_max_flow() {
        // s -> a -> t and s -> b -> t, caps 3 and 2
        let mut g = FlowNetwork::new(4);
        let (s, a, b, t) = (0, 1, 2, 3);
        g.add_edge(s, a, 3.0, 0.0);
        g.add_edge(a, t, 3.0, 0.0);
        g.add_edge(s, b, 2.0, 0.0);
        g.add_edge(b, t, 2.0, 0.0);
        let (flow, cost) = g.min_cost_max_flow(s, t, f64::INFINITY);
        assert!((flow - 5.0).abs() < 1e-9);
        assert!(cost.abs() < 1e-9);
    }

    #[test]
    fn prefers_cheap_path() {
        // two parallel paths, expensive one only used after cheap saturates
        let mut g = FlowNetwork::new(4);
        let (s, a, b, t) = (0, 1, 2, 3);
        let cheap = g.add_edge(s, a, 1.0, 1.0);
        g.add_edge(a, t, 1.0, 0.0);
        let dear = g.add_edge(s, b, 1.0, 5.0);
        g.add_edge(b, t, 1.0, 0.0);
        let (flow, cost) = g.min_cost_max_flow(s, t, 1.0);
        assert!((flow - 1.0).abs() < 1e-9);
        assert!((cost - 1.0).abs() < 1e-9);
        assert!((g.flow_on(cheap) - 1.0).abs() < 1e-9);
        assert!(g.flow_on(dear).abs() < 1e-9);
    }

    #[test]
    fn reroutes_through_residual_arcs() {
        // Classic rerouting: the min-cost max-flow must push 2 units even
        // though the greedy first path blocks the middle edge.
        let mut g = FlowNetwork::new(4);
        let (s, a, b, t) = (0, 1, 2, 3);
        g.add_edge(s, a, 1.0, 0.0);
        g.add_edge(s, b, 1.0, 2.0);
        g.add_edge(a, b, 1.0, 0.0);
        g.add_edge(a, t, 1.0, 3.0);
        g.add_edge(b, t, 2.0, 0.0);
        let (flow, cost) = g.min_cost_max_flow(s, t, f64::INFINITY);
        assert!((flow - 2.0).abs() < 1e-9, "flow={flow}");
        // cheapest 2-unit flow: s->a->b->t (0) + s->b->t (2) = 2
        assert!((cost - 2.0).abs() < 1e-9, "cost={cost}");
    }

    #[test]
    fn respects_flow_limit() {
        let mut g = FlowNetwork::new(2);
        g.add_edge(0, 1, 10.0, 1.0);
        let (flow, cost) = g.min_cost_max_flow(0, 1, 2.5);
        assert!((flow - 2.5).abs() < 1e-9);
        assert!((cost - 2.5).abs() < 1e-9);
    }

    #[test]
    fn reset_reuses_network_with_identical_results() {
        let mut g = FlowNetwork::new(4);
        let (s, a, b, t) = (0, 1, 2, 3);
        g.add_edge(s, a, 3.0, 0.0);
        g.add_edge(a, t, 3.0, 0.0);
        g.add_edge(s, b, 2.0, 0.0);
        g.add_edge(b, t, 2.0, 0.0);
        let (f1, c1) = g.min_cost_max_flow(s, t, f64::INFINITY);
        // rebuild the same graph in the same network and re-solve
        g.reset(4);
        g.add_edge(s, a, 3.0, 0.0);
        g.add_edge(a, t, 3.0, 0.0);
        g.add_edge(s, b, 2.0, 0.0);
        g.add_edge(b, t, 2.0, 0.0);
        let (f2, c2) = g.min_cost_max_flow(s, t, f64::INFINITY);
        assert_eq!(f1, f2);
        assert_eq!(c1, c2);
        // shrink then grow node count
        g.reset(2);
        g.add_edge(0, 1, 1.5, 0.0);
        let (f3, _) = g.min_cost_max_flow(0, 1, f64::INFINITY);
        assert!((f3 - 1.5).abs() < 1e-12);
    }

    #[test]
    fn disconnected_gives_zero() {
        let mut g = FlowNetwork::new(3);
        g.add_edge(0, 1, 1.0, 0.0);
        let (flow, _) = g.min_cost_max_flow(0, 2, f64::INFINITY);
        assert_eq!(flow, 0.0);
    }

    #[test]
    fn handles_negative_costs_from_zero_flow() {
        // negative-cost arc: SSP from zero flow stays optimal
        let mut g = FlowNetwork::new(3);
        g.add_edge(0, 1, 1.0, -5.0);
        g.add_edge(1, 2, 1.0, 0.0);
        g.add_edge(0, 2, 1.0, -1.0);
        let (flow, cost) = g.min_cost_max_flow(0, 2, f64::INFINITY);
        assert!((flow - 2.0).abs() < 1e-9);
        assert!((cost + 6.0).abs() < 1e-9);
    }
}
