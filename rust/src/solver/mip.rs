//! Solvers for the paper's per-round client-selection MILP (§4.3):
//!
//!   max  Σ_c b_c σ_c Σ_t m_{c,t}
//!   s.t. b_c = 1 ⇒ m_min ≤ Σ_t m_{c,t} ≤ m_max,  m_{c,t} ≤ spare_{c,t}
//!        Σ_{c∈p} δ_c m_{c,t} ≤ r_{p,t}   ∀ p, t
//!        Σ_c b_c = n
//!
//! For fixed b the problem decomposes per power domain into the exact
//! transportation flow of [`super::alloc`]. Three solvers over b:
//!
//! * [`greedy`] — the scalable default (standalone-score ordering +
//!   feasibility-checked insertion + swap local search). O(C·T) filter
//!   cost; reproduces the paper's Fig-8 scalability envelope.
//! * [`branch_and_bound`] — exact on evaluation-scale instances, using the
//!   admissible bound Σ σ_c·standalone_c and infeasibility pruning
//!   (infeasible partial selections stay infeasible for supersets); falls
//!   back to the greedy incumbent when the node budget runs out.
//! * [`enumerate`] — brute force over all C-choose-n subsets; ground truth
//!   for tests on tiny instances.

use super::alloc::{AllocClient, AllocProblem};

/// One eligible (pre-filtered) candidate client.
#[derive(Clone, Debug)]
pub struct SelClient {
    /// power-domain index
    pub domain: usize,
    /// statistical utility σ_c
    pub sigma: f64,
    /// energy per batch, Wh
    pub delta: f64,
    pub m_min: f64,
    pub m_max: f64,
    /// forecast spare capacity per step (batches)
    pub spare: Vec<f64>,
}

/// A selection instance for a fixed candidate round duration `d` (= the
/// length of every `spare` / `energy` vector).
#[derive(Clone, Debug)]
pub struct SelInstance {
    pub n: usize,
    pub clients: Vec<SelClient>,
    /// excess-energy forecast per domain per step, Wh
    pub energy: Vec<Vec<f64>>,
}

#[derive(Clone, Debug)]
pub struct SelSolution {
    /// indices into `instance.clients`
    pub chosen: Vec<usize>,
    pub objective: f64,
    /// expected total batches per chosen client (same order as `chosen`)
    pub totals: Vec<f64>,
    /// true iff produced by an exact method that ran to completion
    pub optimal: bool,
}

impl SelClient {
    fn as_alloc(&self) -> AllocClient {
        AllocClient {
            min_batches: self.m_min,
            max_batches: self.m_max,
            delta: self.delta,
            weight: self.sigma,
            spare: self.spare.clone(),
        }
    }

    pub fn standalone_batches(&self, energy: &[f64]) -> f64 {
        AllocProblem::standalone_batches(&self.as_alloc(), energy)
    }
}

impl SelInstance {
    /// Exact objective + per-client totals for a fixed selection, or `None`
    /// if the joint m_min lower bounds are infeasible. Decomposes per
    /// domain.
    pub fn evaluate(&self, chosen: &[usize]) -> Option<(f64, Vec<f64>)> {
        let mut by_domain: Vec<Vec<usize>> = vec![Vec::new(); self.energy.len()];
        for &i in chosen {
            by_domain[self.clients[i].domain].push(i);
        }
        let mut objective = 0.0;
        let mut totals = vec![0.0; chosen.len()];
        let pos: std::collections::HashMap<usize, usize> =
            chosen.iter().enumerate().map(|(k, &i)| (i, k)).collect();
        for (p, members) in by_domain.iter().enumerate() {
            if members.is_empty() {
                continue;
            }
            let prob = AllocProblem {
                clients: members
                    .iter()
                    .map(|&i| self.clients[i].as_alloc())
                    .collect(),
                energy: self.energy[p].clone(),
            };
            let a = prob.solve()?;
            objective += a.objective;
            for (k, &i) in members.iter().enumerate() {
                totals[pos[&i]] = a.totals[k];
            }
        }
        Some((objective, totals))
    }

    /// σ_c · standalone upper bound per candidate (admissible: a client can
    /// never compute more jointly than alone).
    pub fn standalone_scores(&self) -> Vec<f64> {
        self.clients
            .iter()
            .map(|c| c.sigma * c.standalone_batches(&self.energy[c.domain]))
            .collect()
    }
}

/// Greedy + swap local search. Returns at most `n` clients; fewer means no
/// feasible way to add more was found (Algorithm 1 then grows `d`).
///
/// Perf note (§Perf): the allocation problem decomposes per power domain,
/// so both the insertion loop and the swap search re-solve ONLY the
/// affected domain(s) and patch cached per-domain objectives — this turned
/// selection from O(n·D) flow solves per insertion into O(1).
pub fn greedy(inst: &SelInstance, swap_passes: usize) -> SelSolution {
    let scores = inst.standalone_scores();
    let mut order: Vec<usize> = (0..inst.clients.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());

    let n_domains = inst.energy.len();
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_domains];
    let mut dom_obj = vec![0.0f64; n_domains];
    let mut chosen: Vec<usize> = Vec::with_capacity(inst.n);

    // solve one domain's allocation for a member set
    let eval_domain = |doms: usize, mem: &[usize]| -> Option<f64> {
        if mem.is_empty() {
            return Some(0.0);
        }
        let prob = crate::solver::alloc::AllocProblem {
            clients: mem.iter().map(|&i| inst.clients[i].as_alloc()).collect(),
            energy: inst.energy[doms].clone(),
        };
        prob.solve().map(|a| a.objective)
    };

    for &cand in &order {
        if chosen.len() == inst.n {
            break;
        }
        if scores[cand] <= 0.0 {
            continue; // cannot contribute
        }
        let p = inst.clients[cand].domain;
        members[p].push(cand);
        match eval_domain(p, &members[p]) {
            Some(obj) => {
                dom_obj[p] = obj;
                chosen.push(cand);
            }
            None => {
                members[p].pop();
            }
        }
    }

    // Swap local search: replace a chosen client with an unchosen one when
    // it improves the exact objective. Only the source/target domains are
    // re-solved.
    for _ in 0..swap_passes {
        let mut improved = false;
        for slot in 0..chosen.len() {
            let original = chosen[slot];
            let p1 = inst.clients[original].domain;
            // domain p1 without `original` (computed once per slot)
            let mem_minus: Vec<usize> = members[p1]
                .iter()
                .copied()
                .filter(|&c| c != original)
                .collect();
            let Some(obj1_minus) = eval_domain(p1, &mem_minus) else {
                continue; // removing should never be infeasible, but be safe
            };
            let mut best_swap: Option<(usize, f64)> = None; // (cand, delta)
            for &cand in &order {
                if scores[cand] <= 0.0 {
                    continue;
                }
                if chosen.contains(&cand) {
                    continue;
                }
                let p2 = inst.clients[cand].domain;
                let delta = if p2 == p1 {
                    let mut mem = mem_minus.clone();
                    mem.push(cand);
                    match eval_domain(p1, &mem) {
                        Some(obj) => obj - dom_obj[p1],
                        None => continue,
                    }
                } else {
                    let mut mem2 = members[p2].clone();
                    mem2.push(cand);
                    match eval_domain(p2, &mem2) {
                        Some(obj2) => {
                            (obj1_minus - dom_obj[p1]) + (obj2 - dom_obj[p2])
                        }
                        None => continue,
                    }
                };
                if delta > 1e-9
                    && best_swap.map(|(_, b)| delta > b).unwrap_or(true)
                {
                    best_swap = Some((cand, delta));
                }
            }
            if let Some((cand, _)) = best_swap {
                // apply: remove original from p1, add cand to its domain
                let p2 = inst.clients[cand].domain;
                members[p1].retain(|&c| c != original);
                members[p2].push(cand);
                dom_obj[p1] = eval_domain(p1, &members[p1])
                    .expect("removal made domain infeasible");
                dom_obj[p2] = eval_domain(p2, &members[p2])
                    .expect("accepted swap became infeasible");
                chosen[slot] = cand;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }

    let (objective, totals) = inst
        .evaluate(&chosen)
        .expect("greedy kept an infeasible selection");
    SelSolution { chosen, objective, totals, optimal: false }
}

/// Exact branch-and-bound. `node_budget` caps the search; on exhaustion the
/// best incumbent (at least as good as greedy) is returned with
/// `optimal = false`.
pub fn branch_and_bound(inst: &SelInstance, node_budget: usize) -> SelSolution {
    let scores = inst.standalone_scores();
    let mut order: Vec<usize> = (0..inst.clients.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    // prefix sums of sorted scores for the completion bound
    let sorted_scores: Vec<f64> = order.iter().map(|&i| scores[i]).collect();

    let seed = greedy(inst, 1);
    let mut best =
        if seed.chosen.len() == inst.n { seed.clone() } else { seed.clone() };
    let best_obj = if best.chosen.len() == inst.n {
        best.objective
    } else {
        f64::NEG_INFINITY
    };

    struct Dfs<'a> {
        inst: &'a SelInstance,
        order: &'a [usize],
        sorted_scores: &'a [f64],
        nodes: usize,
        budget: usize,
        best_obj: f64,
        best: Option<(Vec<usize>, f64, Vec<f64>)>,
        complete: bool,
    }

    impl<'a> Dfs<'a> {
        /// admissible upper bound: exact standalone sum of chosen + top
        /// remaining standalone scores from position `idx`.
        fn bound(&self, chosen_score: f64, idx: usize, need: usize) -> f64 {
            let mut b = chosen_score;
            let mut taken = 0;
            let mut i = idx;
            while taken < need && i < self.sorted_scores.len() {
                if self.sorted_scores[i] > 0.0 {
                    b += self.sorted_scores[i];
                }
                taken += 1;
                i += 1;
            }
            b
        }

        fn run(&mut self, chosen: &mut Vec<usize>, chosen_score: f64, idx: usize) {
            if self.nodes >= self.budget {
                self.complete = false;
                return;
            }
            self.nodes += 1;
            let need = self.inst.n - chosen.len();
            if need == 0 {
                if let Some((obj, totals)) = self.inst.evaluate(chosen) {
                    if obj > self.best_obj + 1e-12 {
                        self.best_obj = obj;
                        self.best = Some((chosen.clone(), obj, totals));
                    }
                }
                return;
            }
            if idx >= self.order.len()
                || self.order.len() - idx < need
                || self.bound(chosen_score, idx, need) <= self.best_obj + 1e-12
            {
                return;
            }
            let cand = self.order[idx];
            // Branch 1: include (prune infeasible partial selections — the
            // joint lower bounds only tighten as the set grows).
            chosen.push(cand);
            if self.inst.evaluate(chosen).is_some() {
                self.run(
                    chosen,
                    chosen_score + self.sorted_scores[idx],
                    idx + 1,
                );
            }
            chosen.pop();
            // Branch 2: exclude
            self.run(chosen, chosen_score, idx + 1);
        }
    }

    let mut dfs = Dfs {
        inst,
        order: &order,
        sorted_scores: &sorted_scores,
        nodes: 0,
        budget: node_budget,
        best_obj,
        best: None,
        complete: true,
    };
    let mut chosen = Vec::new();
    dfs.run(&mut chosen, 0.0, 0);

    if let Some((chosen, objective, totals)) = dfs.best {
        SelSolution { chosen, objective, totals, optimal: dfs.complete }
    } else if best_obj > f64::NEG_INFINITY {
        best.optimal = dfs.complete;
        best
    } else {
        // No feasible size-n selection exists (or was found): return the
        // (possibly shorter) greedy solution, marked exact if search
        // completed.
        best.optimal = dfs.complete;
        best
    }
}

/// Brute force over all subsets of size n (tests only; panics on big C).
pub fn enumerate(inst: &SelInstance) -> Option<SelSolution> {
    let c = inst.clients.len();
    assert!(c <= 20, "enumerate() is for tiny instances");
    let mut best: Option<SelSolution> = None;
    let mut subset: Vec<usize> = Vec::new();

    fn rec(
        inst: &SelInstance,
        start: usize,
        subset: &mut Vec<usize>,
        best: &mut Option<SelSolution>,
    ) {
        if subset.len() == inst.n {
            if let Some((obj, totals)) = inst.evaluate(subset) {
                let better = best
                    .as_ref()
                    .map(|b| obj > b.objective + 1e-12)
                    .unwrap_or(true);
                if better {
                    *best = Some(SelSolution {
                        chosen: subset.clone(),
                        objective: obj,
                        totals,
                        optimal: true,
                    });
                }
            }
            return;
        }
        if inst.clients.len() - start < inst.n - subset.len() {
            return;
        }
        for i in start..inst.clients.len() {
            subset.push(i);
            rec(inst, i + 1, subset, best);
            subset.pop();
        }
    }

    rec(inst, 0, &mut subset, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_instance(seed: u64, c_n: usize, p_n: usize, t_n: usize, n: usize) -> SelInstance {
        let mut rng = Rng::new(seed);
        let clients = (0..c_n)
            .map(|_| {
                let m_min = rng.range_f64(0.5, 2.0);
                SelClient {
                    domain: rng.below(p_n),
                    sigma: rng.range_f64(0.1, 3.0),
                    delta: rng.range_f64(0.5, 2.5),
                    m_min,
                    m_max: m_min + rng.range_f64(0.0, 6.0),
                    spare: (0..t_n).map(|_| rng.range_f64(0.0, 2.0)).collect(),
                }
            })
            .collect();
        let energy = (0..p_n)
            .map(|_| (0..t_n).map(|_| rng.range_f64(0.0, 5.0)).collect())
            .collect();
        SelInstance { n, clients, energy }
    }

    #[test]
    fn bnb_matches_enumeration() {
        let mut compared = 0;
        for seed in 0..25u64 {
            let inst = random_instance(seed, 7, 2, 4, 3);
            let exact = enumerate(&inst);
            let bnb = branch_and_bound(&inst, 1_000_000);
            match exact {
                Some(e) => {
                    assert!(bnb.optimal, "seed {seed}: budget exhausted");
                    assert_eq!(bnb.chosen.len(), inst.n, "seed {seed}");
                    assert!(
                        (e.objective - bnb.objective).abs()
                            < 1e-6 * (1.0 + e.objective),
                        "seed {seed}: enum={} bnb={}",
                        e.objective,
                        bnb.objective
                    );
                    compared += 1;
                }
                None => {
                    assert!(
                        bnb.chosen.len() < inst.n,
                        "seed {seed}: bnb found selection but enum says infeasible"
                    );
                }
            }
        }
        assert!(compared >= 10, "too few feasible instances: {compared}");
    }

    #[test]
    fn greedy_is_feasible_and_near_optimal() {
        let mut ratios = Vec::new();
        for seed in 100..130u64 {
            let inst = random_instance(seed, 8, 3, 4, 3);
            let g = greedy(&inst, 2);
            // whatever greedy chose must be feasible
            assert!(inst.evaluate(&g.chosen).is_some());
            if let Some(e) = enumerate(&inst) {
                if g.chosen.len() == inst.n && e.objective > 1e-9 {
                    ratios.push(g.objective / e.objective);
                }
            }
        }
        assert!(!ratios.is_empty());
        let worst = ratios.iter().cloned().fold(1.0, f64::min);
        let avg: f64 = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(worst > 0.65, "worst greedy/opt ratio {worst}");
        assert!(avg > 0.9, "avg greedy/opt ratio {avg}");
    }

    #[test]
    fn greedy_respects_n() {
        let inst = random_instance(7, 12, 3, 5, 4);
        let g = greedy(&inst, 1);
        assert!(g.chosen.len() <= 4);
        let mut uniq = g.chosen.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), g.chosen.len(), "duplicate selection");
    }

    #[test]
    fn infeasible_instance_yields_partial_selection() {
        // no energy at all -> nobody can reach m_min
        let inst = SelInstance {
            n: 2,
            clients: vec![
                SelClient {
                    domain: 0,
                    sigma: 1.0,
                    delta: 1.0,
                    m_min: 1.0,
                    m_max: 5.0,
                    spare: vec![1.0; 3],
                },
                SelClient {
                    domain: 0,
                    sigma: 1.0,
                    delta: 1.0,
                    m_min: 1.0,
                    m_max: 5.0,
                    spare: vec![1.0; 3],
                },
            ],
            energy: vec![vec![0.0; 3]],
        };
        let g = greedy(&inst, 1);
        assert!(g.chosen.is_empty());
        let b = branch_and_bound(&inst, 10_000);
        assert!(b.chosen.is_empty());
    }

    #[test]
    fn shared_domain_competition_prefers_split() {
        // Two domains, each with energy for ~1 client; three candidates,
        // two of them in domain 0. Optimal picks one from each domain.
        let mk = |domain: usize, sigma: f64| SelClient {
            domain,
            sigma,
            delta: 1.0,
            m_min: 2.0,
            m_max: 4.0,
            spare: vec![2.0; 2],
        };
        let inst = SelInstance {
            n: 2,
            clients: vec![mk(0, 1.0), mk(0, 1.0), mk(1, 0.9)],
            energy: vec![vec![2.0; 2], vec![2.0; 2]],
        };
        let e = enumerate(&inst).unwrap();
        let domains: Vec<usize> =
            e.chosen.iter().map(|&i| inst.clients[i].domain).collect();
        assert!(domains.contains(&0) && domains.contains(&1), "{domains:?}");
        let g = greedy(&inst, 2);
        assert_eq!(g.chosen.len(), 2);
        assert!(
            (g.objective - e.objective).abs() < 1e-6,
            "greedy {} vs opt {}",
            g.objective,
            e.objective
        );
    }
}
