//! Solvers for the paper's per-round client-selection MILP (§4.3):
//!
//!   max  Σ_c b_c σ_c Σ_t m_{c,t}
//!   s.t. b_c = 1 ⇒ m_min ≤ Σ_t m_{c,t} ≤ m_max,  m_{c,t} ≤ spare_{c,t}
//!        Σ_{c∈p} δ_c m_{c,t} ≤ r_{p,t}   ∀ p, t
//!        Σ_c b_c = n
//!
//! For fixed b the problem decomposes per power domain into the exact
//! transportation flow of [`super::alloc`]. Three solvers over b:
//!
//! * [`greedy`] — the scalable default (standalone-score ordering +
//!   feasibility-checked insertion + swap local search). O(C·T) filter
//!   cost; reproduces the paper's Fig-8 scalability envelope.
//! * [`branch_and_bound`] — exact on evaluation-scale instances, using two
//!   stacked admissible bounds — Σ σ_c·standalone_c over the top remaining
//!   candidates, and the per-domain energy-capacity cap ρ_p^max·E_p (a
//!   domain cannot serve the sum of its members' standalone values; see
//!   [`branch_and_bound_view`]) — plus infeasibility pruning (infeasible
//!   partial selections stay infeasible for supersets); falls back to the
//!   greedy incumbent when the node budget runs out. At scale,
//!   independent root subtrees fan out across `util::par` workers with a
//!   shared atomic incumbent; strict pruning plus a canonical
//!   (objective, lex-smallest-selection) reduction makes the parallel
//!   result identical to the serial one on completed searches.
//! * [`enumerate`] — brute force over all C-choose-n subsets; ground truth
//!   for tests on tiny instances.
//!
//! §Perf — the Fig-8 scale path. The solvers run on borrowed views
//! ([`InstanceView`] / [`ClientView`]) whose `spare`/`energy` rows are
//! `f32` slices straight into the persistent forecast ring-arena the
//! simulator advances incrementally (see `selection::ring` and
//! `selection::arena`; f64 widening happens here, at the arithmetic), so
//! a binary-search probe over the round duration `d` re-slices the
//! `d_max` window instead of re-materialising every forecast, and no
//! solver layer clones a spare or energy vector
//! (the historical `SelClient::as_alloc` spare clone, `eval_domain`
//! energy clone, and per-probe `w[..d].to_vec()` are all gone). On top:
//!
//! * one-member domains are evaluated in closed form — a singleton
//!   domain's exact optimum is σ·min(standalone, m_max), precomputed for
//!   every candidate — which removes the flow solve from the vast
//!   majority of swap evaluations when domains outnumber the cohort;
//! * the swap local search tracks membership in an O(1) bitset instead
//!   of the O(n) `chosen.contains` scan, and scans candidates in
//!   parallel chunks (`util::par`, std::thread fork-join; rayon is not
//!   in the offline vendor set) with a deterministic first-max merge, so
//!   parallel and serial runs pick identical swaps;
//! * standalone scoring and multi-domain evaluation fan out the same
//!   way, and every flow solve reuses one [`AllocWorkspace`] so the
//!   steady state allocates nothing.
//!
//! [`reference_greedy`] retains the pre-arena implementation (owned
//! clones, linear membership scans, per-eval allocations) both as the
//! oracle for the equivalence property tests below — identical `chosen`
//! and objectives to 1e-9 on seeded random instances — and as the
//! baseline the selection bench measures speedups against
//! (`BENCH_selection.json`, field `speedup_vs_reference`).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use super::alloc::{
    self, AllocClient, AllocClientView, AllocProblem, AllocWorkspace,
};
use crate::util::obs::{self, Ctr, Hist};
use crate::util::par;
// The fan-out thresholds live in ONE documented table (they used to be
// duplicated per module and could drift): see `util::par::thresholds`.
use crate::util::par::thresholds::{
    BNB_MIN_CLIENTS as PAR_MIN_BNB_CLIENTS, MIN_CLIENTS as PAR_MIN_CLIENTS,
    MIN_DOMAIN_GROUPS as PAR_MIN_DOMAIN_GROUPS, MIN_EVAL_WORK as PAR_MIN_EVAL_WORK,
};

/// One eligible (pre-filtered) candidate client (owned builder form).
#[derive(Clone, Debug)]
pub struct SelClient {
    /// power-domain index
    pub domain: usize,
    /// statistical utility σ_c
    pub sigma: f64,
    /// energy per batch, Wh
    pub delta: f64,
    pub m_min: f64,
    pub m_max: f64,
    /// forecast spare capacity per step (batches; f32 — the forecast
    /// arena element type, widened to f64 at solver arithmetic)
    pub spare: Vec<f32>,
}

/// A selection instance for a fixed candidate round duration `d` (= the
/// length of every `spare` / `energy` vector). Owned builder form; the
/// solvers run on [`InstanceView`]s.
#[derive(Clone, Debug)]
pub struct SelInstance {
    pub n: usize,
    pub clients: Vec<SelClient>,
    /// excess-energy forecast per domain per step, Wh (f32, see `spare`)
    pub energy: Vec<Vec<f32>>,
}

/// Borrowed, `Copy` view of one candidate: scalars plus a slice into the
/// forecast arena (or into an owned [`SelClient`]).
#[derive(Clone, Copy, Debug)]
pub struct ClientView<'a> {
    pub domain: usize,
    pub sigma: f64,
    pub delta: f64,
    pub m_min: f64,
    pub m_max: f64,
    pub spare: &'a [f32],
}

impl<'a> ClientView<'a> {
    #[inline]
    fn as_alloc(&self) -> AllocClientView<'a> {
        AllocClientView {
            min_batches: self.m_min,
            max_batches: self.m_max,
            delta: self.delta,
            weight: self.sigma,
            spare: self.spare,
        }
    }
}

/// Borrowed selection instance: what every solver actually runs on.
#[derive(Clone, Copy, Debug)]
pub struct InstanceView<'a> {
    pub n: usize,
    pub clients: &'a [ClientView<'a>],
    pub energy: &'a [&'a [f32]],
}

/// Backing storage adapting an owned [`SelInstance`] to views.
pub struct ViewStorage<'a> {
    pub n: usize,
    clients: Vec<ClientView<'a>>,
    energy: Vec<&'a [f32]>,
}

impl<'a> ViewStorage<'a> {
    pub fn view(&self) -> InstanceView<'_> {
        InstanceView { n: self.n, clients: &self.clients, energy: &self.energy }
    }
}

#[derive(Clone, Debug)]
pub struct SelSolution {
    /// indices into `instance.clients`
    pub chosen: Vec<usize>,
    pub objective: f64,
    /// expected total batches per chosen client (same order as `chosen`)
    pub totals: Vec<f64>,
    /// true iff produced by an exact method that ran to completion
    pub optimal: bool,
}

impl SelClient {
    pub fn standalone_batches(&self, energy: &[f32]) -> f64 {
        alloc::standalone_batches_view(&self.spare, self.delta, self.m_max, energy)
    }
}

impl SelInstance {
    pub fn view_storage(&self) -> ViewStorage<'_> {
        ViewStorage {
            n: self.n,
            clients: self
                .clients
                .iter()
                .map(|c| ClientView {
                    domain: c.domain,
                    sigma: c.sigma,
                    delta: c.delta,
                    m_min: c.m_min,
                    m_max: c.m_max,
                    spare: &c.spare,
                })
                .collect(),
            energy: self.energy.iter().map(|e| e.as_slice()).collect(),
        }
    }

    /// Exact objective + per-client totals for a fixed selection, or `None`
    /// if the joint m_min lower bounds are infeasible. Decomposes per
    /// domain.
    pub fn evaluate(&self, chosen: &[usize]) -> Option<(f64, Vec<f64>)> {
        let vs = self.view_storage();
        let mut ws = AllocWorkspace::default();
        evaluate_view(&vs.view(), chosen, &mut ws)
    }

    /// σ_c · standalone upper bound per candidate (admissible: a client can
    /// never compute more jointly than alone).
    pub fn standalone_scores(&self) -> Vec<f64> {
        let vs = self.view_storage();
        standalone_scores_view(&vs.view())
    }
}

/// σ_c · standalone score per candidate, fanned out across threads at
/// scale (results identical to the serial map).
pub fn standalone_scores_view(inst: &InstanceView<'_>) -> Vec<f64> {
    par::par_map(inst.clients.len(), PAR_MIN_CLIENTS, |i| {
        let c = &inst.clients[i];
        c.sigma
            * alloc::standalone_batches_view(
                c.spare,
                c.delta,
                c.m_max,
                inst.energy[c.domain],
            )
    })
}

/// Exact objective + totals of a fixed selection on a view instance.
/// Domain groups are solved independently (in parallel once the group
/// count justifies it) and merged in ascending-domain order, matching
/// the historical sequential accumulation bit for bit.
pub fn evaluate_view<'a>(
    inst: &InstanceView<'a>,
    chosen: &[usize],
    ws: &mut AllocWorkspace,
) -> Option<(f64, Vec<f64>)> {
    let k = chosen.len();
    // group chosen positions by domain, preserving chosen order within a
    // domain (stable sort) — the flow's client order, hence its float
    // result, matches the historical per-domain bucket construction
    let mut pos_by_dom: Vec<usize> = (0..k).collect();
    pos_by_dom.sort_by_key(|&j| inst.clients[chosen[j]].domain);
    let mut groups: Vec<(usize, usize)> = Vec::new();
    let mut start = 0;
    while start < k {
        let p = inst.clients[chosen[pos_by_dom[start]]].domain;
        let mut end = start + 1;
        while end < k && inst.clients[chosen[pos_by_dom[end]]].domain == p {
            end += 1;
        }
        groups.push((start, end));
        start = end;
    }

    let solve_group = |range: (usize, usize),
                       cbuf: &mut Vec<AllocClientView<'a>>,
                       ws: &mut AllocWorkspace|
     -> Option<(f64, Vec<f64>)> {
        let group = &pos_by_dom[range.0..range.1];
        let p = inst.clients[chosen[group[0]]].domain;
        if group.len() == 1 {
            // singleton closed form, with a strictly LOOSER feasibility
            // tolerance (2e-6/δ) than the insertion path's 1e-6/δ and
            // the flow's 1e-6 energy units: a selection accepted during
            // greedy insertion/swaps (either tolerance, ±1 ulp) can
            // never be rejected here, so the "kept an infeasible
            // selection" panic path is unreachable on knife-edge m_min
            let c = &inst.clients[chosen[group[0]]];
            let sb = alloc::standalone_batches_view(
                c.spare, c.delta, c.m_max, inst.energy[p],
            );
            if sb + 2e-6 / c.delta >= c.m_min {
                return Some((c.sigma * sb, vec![sb]));
            }
            return None;
        }
        cbuf.clear();
        cbuf.extend(group.iter().map(|&j| inst.clients[chosen[j]].as_alloc()));
        alloc::solve_full(cbuf, inst.energy[p], ws)
            .map(|a| (a.objective, a.totals))
    };

    let steps = inst.energy.first().map(|e| e.len()).unwrap_or(0);
    let results: Vec<Option<(f64, Vec<f64>)>> =
        if groups.len() >= PAR_MIN_DOMAIN_GROUPS
            && k * steps >= PAR_MIN_EVAL_WORK
            && par::threads() > 1
        {
            par::par_map(groups.len(), 0, |gi| {
                let mut cbuf = Vec::new();
                let mut local_ws = AllocWorkspace::default();
                solve_group(groups[gi], &mut cbuf, &mut local_ws)
            })
        } else {
            let mut cbuf = Vec::new();
            groups
                .iter()
                .map(|&g| solve_group(g, &mut cbuf, ws))
                .collect()
        };

    let mut objective = 0.0;
    let mut totals = vec![0.0; k];
    for (gi, res) in results.into_iter().enumerate() {
        let (obj, group_totals) = res?;
        objective += obj;
        let group = &pos_by_dom[groups[gi].0..groups[gi].1];
        for (g, &j) in group.iter().enumerate() {
            totals[j] = group_totals[g];
        }
    }
    Some((objective, totals))
}

/// One domain's exact allocation objective for a member set.
///
/// Zero and one-member domains are closed forms (a singleton's optimum is
/// σ·min(standalone, m_max), feasible iff standalone reaches m_min);
/// larger sets run the transportation flow on the shared workspace.
fn eval_domain<'a>(
    inst: &InstanceView<'a>,
    scores: &[f64],
    standalone: &[f64],
    p: usize,
    mem: &[usize],
    cbuf: &mut Vec<AllocClientView<'a>>,
    ws: &mut AllocWorkspace,
) -> Option<f64> {
    match mem.len() {
        0 => Some(0.0),
        1 => {
            let i = mem[0];
            // same feasibility tolerance as the flow solver's phase-1
            // check (1e-6 energy units = 1e-6/δ batches), so the closed
            // form and the flow agree on knife-edge m_min instances
            if standalone[i] + 1e-6 / inst.clients[i].delta >= inst.clients[i].m_min {
                Some(scores[i])
            } else {
                None
            }
        }
        _ => {
            cbuf.clear();
            cbuf.extend(mem.iter().map(|&i| inst.clients[i].as_alloc()));
            alloc::solve_objective(cbuf, inst.energy[p], ws)
        }
    }
}

/// Best swap candidate for `slot` (whose client was `original`, domain
/// `p1`): highest positive objective delta, ties to the earliest position
/// in `order` — exactly the sequential scan's first-max semantics, but
/// chunked across threads at scale with a deterministic merge.
///
/// Returns `(cand, delta, obj_new)` where `obj_new` is the winning
/// candidate's domain objective with the candidate included (the new
/// `dom_obj` for that domain), so the caller never re-solves it.
#[allow(clippy::too_many_arguments)]
fn best_swap<'a>(
    inst: &InstanceView<'a>,
    order: &[usize],
    scores: &[f64],
    standalone: &[f64],
    members: &[Vec<usize>],
    dom_obj: &[f64],
    in_chosen: &[bool],
    p1: usize,
    obj1_minus: f64,
    mem_minus: &[usize],
    ws: &mut AllocWorkspace,
    cbuf: &mut Vec<AllocClientView<'a>>,
    mbuf: &mut Vec<usize>,
) -> Option<(usize, f64, f64)> {
    let scan = |start: usize,
                end: usize,
                cbuf: &mut Vec<AllocClientView<'a>>,
                mbuf: &mut Vec<usize>,
                ws: &mut AllocWorkspace|
     -> Option<(f64, usize, f64)> {
        let mut best: Option<(f64, usize, f64)> = None;
        for pos in start..end {
            let cand = order[pos];
            if scores[cand] <= 0.0 {
                continue;
            }
            if in_chosen[cand] {
                continue;
            }
            let p2 = inst.clients[cand].domain;
            let (delta, obj_new) = if p2 == p1 {
                mbuf.clear();
                mbuf.extend_from_slice(mem_minus);
                mbuf.push(cand);
                match eval_domain(inst, scores, standalone, p1, mbuf, cbuf, ws) {
                    Some(obj) => (obj - dom_obj[p1], obj),
                    None => continue,
                }
            } else {
                mbuf.clear();
                mbuf.extend_from_slice(&members[p2]);
                mbuf.push(cand);
                match eval_domain(inst, scores, standalone, p2, mbuf, cbuf, ws) {
                    Some(obj2) => {
                        ((obj1_minus - dom_obj[p1]) + (obj2 - dom_obj[p2]), obj2)
                    }
                    None => continue,
                }
            };
            if delta > 1e-9 && best.map(|(b, _, _)| delta > b).unwrap_or(true) {
                best = Some((delta, pos, obj_new));
            }
        }
        best
    };
    // serial path reuses the caller's workspace/scratch; the parallel
    // fan-out gives each chunk its own (thread-local) set
    let parts: Vec<Option<(f64, usize, f64)>> =
        if order.len() >= PAR_MIN_CLIENTS && par::threads() > 1 {
            par::par_ranges(order.len(), 0, |start, end| {
                let mut ws = AllocWorkspace::default();
                let mut cbuf: Vec<AllocClientView<'a>> = Vec::new();
                let mut mbuf: Vec<usize> = Vec::new();
                scan(start, end, &mut cbuf, &mut mbuf, &mut ws)
            })
        } else {
            vec![scan(0, order.len(), cbuf, mbuf, ws)]
        };
    let mut best: Option<(f64, usize, f64)> = None;
    for p in parts.into_iter().flatten() {
        if best.map(|(b, _, _)| p.0 > b).unwrap_or(true) {
            best = Some(p);
        }
    }
    best.map(|(delta, pos, obj_new)| (order[pos], delta, obj_new))
}

/// Greedy + swap local search on borrowed views (the selection hot path;
/// see the module §Perf notes). Returns at most `n` clients; fewer means
/// no feasible way to add more was found (Algorithm 1 then grows `d`).
pub fn greedy_view<'a>(
    inst: InstanceView<'a>,
    swap_passes: usize,
    ws: &mut AllocWorkspace,
) -> SelSolution {
    let n_clients = inst.clients.len();
    // raw standalone batches double as the singleton-domain closed form
    let standalone: Vec<f64> = par::par_map(n_clients, PAR_MIN_CLIENTS, |i| {
        let c = &inst.clients[i];
        alloc::standalone_batches_view(c.spare, c.delta, c.m_max, inst.energy[c.domain])
    });
    let scores: Vec<f64> = inst
        .clients
        .iter()
        .zip(&standalone)
        .map(|(c, &sb)| c.sigma * sb)
        .collect();
    let mut order: Vec<usize> = (0..n_clients).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());

    let n_domains = inst.energy.len();
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_domains];
    let mut dom_obj = vec![0.0f64; n_domains];
    let mut chosen: Vec<usize> = Vec::with_capacity(inst.n);
    // membership bitset: O(1) "is cand already chosen" in the swap scan
    let mut in_chosen = vec![false; n_clients];
    let mut cbuf: Vec<AllocClientView<'a>> = Vec::new();
    let mut mbuf: Vec<usize> = Vec::new();

    for &cand in &order {
        if chosen.len() == inst.n {
            break;
        }
        if scores[cand] <= 0.0 {
            continue; // cannot contribute
        }
        let p = inst.clients[cand].domain;
        members[p].push(cand);
        match eval_domain(&inst, &scores, &standalone, p, &members[p], &mut cbuf, ws) {
            Some(obj) => {
                dom_obj[p] = obj;
                chosen.push(cand);
                in_chosen[cand] = true;
            }
            None => {
                members[p].pop();
            }
        }
    }

    // Swap local search: replace a chosen client with an unchosen one when
    // it improves the exact objective. Only the source/target domains are
    // re-solved.
    for _ in 0..swap_passes {
        let mut improved = false;
        for slot in 0..chosen.len() {
            let original = chosen[slot];
            let p1 = inst.clients[original].domain;
            // domain p1 without `original` (computed once per slot)
            let mem_minus: Vec<usize> = members[p1]
                .iter()
                .copied()
                .filter(|&c| c != original)
                .collect();
            let Some(obj1_minus) =
                eval_domain(&inst, &scores, &standalone, p1, &mem_minus, &mut cbuf, ws)
            else {
                continue; // removing should never be infeasible, but be safe
            };
            let Some((cand, _delta, obj_new)) = best_swap(
                &inst, &order, &scores, &standalone, &members, &dom_obj,
                &in_chosen, p1, obj1_minus, &mem_minus, ws, &mut cbuf, &mut mbuf,
            ) else {
                continue;
            };
            // apply: remove original from p1, add cand to its domain.
            // No re-solves: members[p1] minus original IS mem_minus
            // (same order), whose objective is obj1_minus, and the
            // scan already evaluated the winning domain as obj_new.
            let p2 = inst.clients[cand].domain;
            members[p1].retain(|&c| c != original);
            members[p2].push(cand);
            if p2 == p1 {
                dom_obj[p1] = obj_new;
            } else {
                dom_obj[p1] = obj1_minus;
                dom_obj[p2] = obj_new;
            }
            in_chosen[original] = false;
            in_chosen[cand] = true;
            chosen[slot] = cand;
            improved = true;
        }
        if !improved {
            break;
        }
    }

    let (objective, totals) = evaluate_view(&inst, &chosen, ws)
        .expect("greedy kept an infeasible selection");
    SelSolution { chosen, objective, totals, optimal: false }
}

/// Greedy + swap local search over an owned instance (builds views once,
/// then runs [`greedy_view`]).
pub fn greedy(inst: &SelInstance, swap_passes: usize) -> SelSolution {
    let vs = inst.view_storage();
    let mut ws = AllocWorkspace::default();
    greedy_view(vs.view(), swap_passes, &mut ws)
}

/// The pre-arena greedy implementation, retained verbatim as the
/// equivalence oracle and the speedup baseline for the selection bench:
/// owned `AllocProblem` construction (spare + energy clones per domain
/// evaluation), O(n) membership scans, a fresh flow network per solve.
/// Must return the same `chosen` and objective as [`greedy`].
pub fn reference_greedy(inst: &SelInstance, swap_passes: usize) -> SelSolution {
    let as_alloc = |c: &SelClient| AllocClient {
        min_batches: c.m_min,
        max_batches: c.m_max,
        delta: c.delta,
        weight: c.sigma,
        spare: c.spare.clone(),
    };
    let scores: Vec<f64> = inst
        .clients
        .iter()
        .map(|c| c.sigma * c.standalone_batches(&inst.energy[c.domain]))
        .collect();
    let mut order: Vec<usize> = (0..inst.clients.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());

    let n_domains = inst.energy.len();
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_domains];
    let mut dom_obj = vec![0.0f64; n_domains];
    let mut chosen: Vec<usize> = Vec::with_capacity(inst.n);

    let eval_domain = |doms: usize, mem: &[usize]| -> Option<f64> {
        if mem.is_empty() {
            return Some(0.0);
        }
        let prob = AllocProblem {
            clients: mem.iter().map(|&i| as_alloc(&inst.clients[i])).collect(),
            energy: inst.energy[doms].clone(),
        };
        prob.solve().map(|a| a.objective)
    };

    for &cand in &order {
        if chosen.len() == inst.n {
            break;
        }
        if scores[cand] <= 0.0 {
            continue;
        }
        let p = inst.clients[cand].domain;
        members[p].push(cand);
        match eval_domain(p, &members[p]) {
            Some(obj) => {
                dom_obj[p] = obj;
                chosen.push(cand);
            }
            None => {
                members[p].pop();
            }
        }
    }

    for _ in 0..swap_passes {
        let mut improved = false;
        for slot in 0..chosen.len() {
            let original = chosen[slot];
            let p1 = inst.clients[original].domain;
            let mem_minus: Vec<usize> = members[p1]
                .iter()
                .copied()
                .filter(|&c| c != original)
                .collect();
            let Some(obj1_minus) = eval_domain(p1, &mem_minus) else {
                continue;
            };
            let mut best_swap: Option<(usize, f64)> = None; // (cand, delta)
            for &cand in &order {
                if scores[cand] <= 0.0 {
                    continue;
                }
                if chosen.contains(&cand) {
                    continue;
                }
                let p2 = inst.clients[cand].domain;
                let delta = if p2 == p1 {
                    let mut mem = mem_minus.clone();
                    mem.push(cand);
                    match eval_domain(p1, &mem) {
                        Some(obj) => obj - dom_obj[p1],
                        None => continue,
                    }
                } else {
                    let mut mem2 = members[p2].clone();
                    mem2.push(cand);
                    match eval_domain(p2, &mem2) {
                        Some(obj2) => {
                            (obj1_minus - dom_obj[p1]) + (obj2 - dom_obj[p2])
                        }
                        None => continue,
                    }
                };
                if delta > 1e-9
                    && best_swap.map(|(_, b)| delta > b).unwrap_or(true)
                {
                    best_swap = Some((cand, delta));
                }
            }
            if let Some((cand, _)) = best_swap {
                let p2 = inst.clients[cand].domain;
                members[p1].retain(|&c| c != original);
                members[p2].push(cand);
                dom_obj[p1] = eval_domain(p1, &members[p1])
                    .expect("removal made domain infeasible");
                dom_obj[p2] = eval_domain(p2, &members[p2])
                    .expect("accepted swap became infeasible");
                chosen[slot] = cand;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }

    // Final evaluation via the historical per-domain owned-flow path —
    // deliberately NOT evaluate_view, so the oracle's objective is fully
    // independent of the new code it is compared against.
    let mut by_domain: Vec<Vec<usize>> = vec![Vec::new(); n_domains];
    for &i in &chosen {
        by_domain[inst.clients[i].domain].push(i);
    }
    let pos: std::collections::HashMap<usize, usize> =
        chosen.iter().enumerate().map(|(k, &i)| (i, k)).collect();
    let mut objective = 0.0;
    let mut totals = vec![0.0; chosen.len()];
    for (p, mem) in by_domain.iter().enumerate() {
        if mem.is_empty() {
            continue;
        }
        let prob = AllocProblem {
            clients: mem.iter().map(|&i| as_alloc(&inst.clients[i])).collect(),
            energy: inst.energy[p].clone(),
        };
        let a = prob.solve().expect("greedy kept an infeasible selection");
        objective += a.objective;
        for (k, &i) in mem.iter().enumerate() {
            totals[pos[&i]] = a.totals[k];
        }
    }
    SelSolution { chosen, objective, totals, optimal: false }
}

/// Order-preserving `u64` key for non-NaN `f64` (a < b ⟺ key(a) <
/// key(b)): lets the shared branch-and-bound incumbent live in one
/// `AtomicU64` with monotone `fetch_max` publication.
#[inline]
fn f64_key(x: f64) -> u64 {
    let b = x.to_bits();
    if b >> 63 == 0 {
        b | (1 << 63)
    } else {
        !b
    }
}

/// Canonical "is (obj, chosen) better than `best`": larger objective
/// wins; EXACT float ties break to the lexicographically smaller
/// selection vector. A strict total preference, so the final reduction
/// is independent of the order solutions were discovered in — the key to
/// serial/parallel identity.
fn better_solution(obj: f64, chosen: &[usize], best: Option<(f64, &[usize])>) -> bool {
    match best {
        None => true,
        Some((bo, bc)) => obj > bo || (obj == bo && chosen < bc),
    }
}

/// Immutable search context + the cross-worker atomics of one
/// branch-and-bound run.
struct BnbShared<'a, 'b> {
    inst: &'b InstanceView<'a>,
    order: &'b [usize],
    sorted_scores: &'b [f64],
    /// ρ_p^max · E_p per domain (fixed)
    dom_cap: &'b [f64],
    /// monotone incumbent objective ([`f64_key`]-encoded). Workers read
    /// it for pruning and `fetch_max` improvements into it; a stale read
    /// only prunes less, never wrongly, so any interleaving is sound.
    incumbent: AtomicU64,
    nodes: AtomicUsize,
    budget: usize,
    exhausted: AtomicBool,
}

/// Worker-local branch-and-bound state.
struct BnbLocal {
    /// Σ positive standalone scores of the undecided (suffix) candidates
    /// per domain — exact save/restore along the DFS path, identical
    /// float sequences in every worker (a pure function of the depth)
    rem_score_sum: Vec<f64>,
    ws: AllocWorkspace,
    best: Option<(f64, Vec<usize>, Vec<f64>)>,
}

/// Admissible upper bound 1: exact standalone sum of chosen + top
/// remaining standalone scores from position `idx`.
fn bnb_bound(sorted_scores: &[f64], chosen_score: f64, idx: usize, need: usize) -> f64 {
    let mut b = chosen_score;
    let mut taken = 0;
    let mut i = idx;
    while taken < need && i < sorted_scores.len() {
        if sorted_scores[i] > 0.0 {
            b += sorted_scores[i];
        }
        taken += 1;
        i += 1;
    }
    b
}

/// Admissible upper bound 2: per-domain energy-capacity cap over the
/// undecided candidates (see [`branch_and_bound_view`]).
fn bnb_domain_bound(rem: &[f64], dom_cap: &[f64], chosen_score: f64) -> f64 {
    let mut b = chosen_score;
    for (r, cap) in rem.iter().zip(dom_cap) {
        b += r.min(*cap);
    }
    b
}

/// The DFS both the serial path and every parallel worker run. Pruning
/// is STRICT (`bound < incumbent`): a subtree whose bound exactly ties
/// the incumbent may still hold an equal-objective, lexicographically
/// smaller selection, so it is explored — which is what makes the final
/// (objective, lex) winner independent of incumbent timing and thus of
/// the worker schedule.
fn bnb_dfs(
    sh: &BnbShared<'_, '_>,
    lo: &mut BnbLocal,
    chosen: &mut Vec<usize>,
    chosen_score: f64,
    idx: usize,
) {
    if sh.nodes.fetch_add(1, Ordering::Relaxed) >= sh.budget {
        sh.exhausted.store(true, Ordering::Relaxed);
        return;
    }
    let need = sh.inst.n - chosen.len();
    if need == 0 {
        if let Some((obj, totals)) = evaluate_view(sh.inst, chosen, &mut lo.ws) {
            let prev = sh.incumbent.fetch_max(f64_key(obj), Ordering::Relaxed);
            if f64_key(obj) > prev {
                obs::add(Ctr::BnbIncumbentUpdates, 1);
            }
            let is_better = better_solution(
                obj,
                chosen,
                lo.best.as_ref().map(|(o, c, _)| (*o, c.as_slice())),
            );
            if is_better {
                lo.best = Some((obj, chosen.clone(), totals));
            }
        }
        return;
    }
    if idx >= sh.order.len() || sh.order.len() - idx < need {
        return;
    }
    let inc = sh.incumbent.load(Ordering::Relaxed);
    if f64_key(bnb_bound(sh.sorted_scores, chosen_score, idx, need)) < inc
        || f64_key(bnb_domain_bound(&lo.rem_score_sum, sh.dom_cap, chosen_score)) < inc
    {
        obs::add(Ctr::BnbBoundCuts, 1);
        return;
    }
    let cand = sh.order[idx];
    // order[idx] leaves the undecided set for both branches: its value is
    // either exact (in chosen_score) or excluded. Exact save/restore so
    // sibling subtrees see identical sums.
    let p = sh.inst.clients[cand].domain;
    let saved_rem = lo.rem_score_sum[p];
    lo.rem_score_sum[p] = saved_rem - sh.sorted_scores[idx].max(0.0);
    // Branch 1: include (prune infeasible partial selections — the joint
    // lower bounds only tighten as the set grows).
    chosen.push(cand);
    if evaluate_view(sh.inst, chosen, &mut lo.ws).is_some() {
        bnb_dfs(sh, lo, chosen, chosen_score + sh.sorted_scores[idx], idx + 1);
    }
    chosen.pop();
    // Branch 2: exclude
    bnb_dfs(sh, lo, chosen, chosen_score, idx + 1);
    lo.rem_score_sum[p] = saved_rem;
}

/// Exact branch-and-bound on borrowed views. `node_budget` caps the
/// search; on exhaustion the best incumbent (at least as good as greedy)
/// is returned with `optimal = false`.
///
/// §Perf — two stacked admissible completion bounds:
///
/// 1. the classic Σ of the top `need` remaining standalone scores;
/// 2. when that fails to prune, a **per-domain energy-capacity cap**: a
///    domain cannot serve the sum of its members' standalone values —
///    whatever subset of remaining candidates is picked, domain p's
///    contribution is at most `min(Σ remaining scores in p,
///    ρ_p^max · E_p)` where `E_p = Σ_t r_{p,t}` is the window's total
///    energy and `ρ_p^max = max σ_c/δ_c` over p's candidates (value per
///    Wh). Both factors upper-bound any feasible per-domain allocation,
///    so the bound stays admissible; on evaluation-scale instances with
///    contended domains it prunes far deeper than bound 1 alone.
///    `rem_score_sum` is maintained by exact save/restore along the DFS
///    path (no float drift across siblings).
///
/// §Perf — parallel subtree fan-out (ROADMAP "Parallel branch-and-
/// bound" + "Deeper B&B work stealing"): above
/// `thresholds::BNB_MIN_CLIENTS` the root is expanded breadth-first
/// into a deterministic frontier of independent subtrees (uniform
/// depth, feasibility-pruned), which workers drain by **work stealing**
/// (`util::par::steal` — frontier subtrees have wildly uneven node
/// counts, so the historical fixed uniform split, kept as
/// [`BnbDrain::Chunked`] for the bench baseline, left workers idle
/// behind one deep subtree) with a SHARED atomic incumbent — bound
/// reads are monotone, so a stale incumbent only prunes less and
/// pruning stays admissible. Results are IDENTICAL serial vs parallel
/// on completed searches: pruning is strict (`bound < incumbent`), so
/// every leaf achieving the global maximum is explored regardless of
/// schedule, and the final reduction picks the
/// maximum objective with exact ties broken to the lexicographically
/// smallest selection (greedy seed included) — a schedule-independent
/// canonical winner (property-tested, and load-tested in
/// `benches/selection.rs`). On budget exhaustion the node accounting is
/// schedule-dependent and only `optimal = false` is guaranteed.
///
/// Trade-off of the strict prune: subtrees whose bound EXACTLY ties the
/// incumbent are explored (they may hold an equal-objective,
/// lex-smaller selection). On tie-dense degenerate instances — many
/// candidates with bit-identical standalone scores whose bound is
/// achieved exactly, e.g. a fresh fleet where every σ_c = 1 on
/// uncontended singleton domains — this enumerates tie completions
/// until `node_budget` caps it and the search falls back to the greedy
/// incumbent with `optimal = false` (the historical epsilon prune cut
/// these early, but made the surviving tie set depend on incumbent
/// timing, which is exactly what breaks serial/parallel identity).
/// Exactness + schedule-independence costs tie exploration; the budget
/// bounds the damage and the fallback is the scalable default solver.
pub fn branch_and_bound_view(
    inst: InstanceView<'_>,
    node_budget: usize,
    ws: &mut AllocWorkspace,
) -> SelSolution {
    let parallel =
        inst.clients.len() >= PAR_MIN_BNB_CLIENTS && par::threads() > 1;
    let drain = if parallel { BnbDrain::Steal } else { BnbDrain::Serial };
    bnb_run(inst, node_budget, ws, drain, 0).0
}

/// How the frontier of independent subtrees is drained. The chosen
/// drain never changes the returned solution — only node throughput —
/// so this is exposed (hidden) purely for the equivalence tests and the
/// steal-vs-uniform bench point.
#[doc(hidden)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BnbDrain {
    /// One DFS over the whole tree, no frontier.
    Serial,
    /// The historical fixed uniform split: contiguous frontier ranges,
    /// one per worker, no redistribution — a skewed subtree leaves the
    /// other workers idle at the join.
    Chunked,
    /// Work stealing over frontier subtrees (`util::par::steal`): an
    /// idle worker steals unexplored subtrees from a busy one.
    Steal,
}

/// [`branch_and_bound_view`] with the parallel fan-out forced on or off,
/// returning the visited node count — the serial/parallel equivalence
/// tests use this. Forced-parallel means the stealing drain.
#[doc(hidden)]
pub fn branch_and_bound_view_forced(
    inst: InstanceView<'_>,
    node_budget: usize,
    ws: &mut AllocWorkspace,
    parallel: bool,
) -> (SelSolution, usize) {
    let drain = if parallel { BnbDrain::Steal } else { BnbDrain::Serial };
    let (sol, nodes, _) = bnb_run(inst, node_budget, ws, drain, 0);
    (sol, nodes)
}

/// [`branch_and_bound_view`] with the drain and worker count pinned
/// (`workers = 0` means auto), additionally returning visited node
/// count and scheduling telemetry — the steal-vs-uniform bench point
/// and the worker-count determinism tests use this.
#[doc(hidden)]
pub fn branch_and_bound_view_drained(
    inst: InstanceView<'_>,
    node_budget: usize,
    ws: &mut AllocWorkspace,
    drain: BnbDrain,
    workers: usize,
) -> (SelSolution, usize, par::steal::StealStats) {
    bnb_run(inst, node_budget, ws, drain, workers)
}

fn bnb_run(
    inst: InstanceView<'_>,
    node_budget: usize,
    ws: &mut AllocWorkspace,
    drain: BnbDrain,
    workers: usize,
) -> (SelSolution, usize, par::steal::StealStats) {
    let _solve_timer = obs::timer(Hist::BnbSolveNs);
    let scores = standalone_scores_view(&inst);
    let mut order: Vec<usize> = (0..inst.clients.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    // sorted scores for the completion bound
    let sorted_scores: Vec<f64> = order.iter().map(|&i| scores[i]).collect();

    // per-domain energy-capacity caps (bound 2): dom_cap[p] = ρ_p^max·E_p,
    // rem_root[p] = Σ positive standalone scores of undecided candidates
    // in p (all of them at the root)
    let n_domains = inst.energy.len();
    let mut dom_cap = vec![0.0f64; n_domains];
    let mut rem_root = vec![0.0f64; n_domains];
    for (p, row) in inst.energy.iter().enumerate() {
        let e_total: f64 = row.iter().map(|&e| e as f64).sum();
        dom_cap[p] = e_total; // scaled by ρ_p^max below
    }
    let mut rho_max = vec![0.0f64; n_domains];
    for (i, c) in inst.clients.iter().enumerate() {
        rem_root[c.domain] += scores[i].max(0.0);
        rho_max[c.domain] = rho_max[c.domain].max(c.sigma / c.delta);
    }
    for (cap, rho) in dom_cap.iter_mut().zip(&rho_max) {
        *cap *= rho;
    }

    let seed = greedy_view(inst, 1, ws);
    let seed_full = seed.chosen.len() == inst.n;
    let seed_obj = if seed_full { seed.objective } else { f64::NEG_INFINITY };

    let shared = BnbShared {
        inst: &inst,
        order: &order,
        sorted_scores: &sorted_scores,
        dom_cap: &dom_cap,
        incumbent: AtomicU64::new(f64_key(seed_obj)),
        nodes: AtomicUsize::new(0),
        budget: node_budget,
        exhausted: AtomicBool::new(false),
    };

    let mut candidates: Vec<(f64, Vec<usize>, Vec<f64>)> = Vec::new();
    let mut steal_stats = par::steal::StealStats::default();
    if drain == BnbDrain::Serial {
        let mut local = BnbLocal {
            rem_score_sum: rem_root,
            ws: std::mem::take(ws),
            best: None,
        };
        let mut chosen = Vec::new();
        bnb_dfs(&shared, &mut local, &mut chosen, 0.0, 0);
        *ws = local.ws;
        if let Some(b) = local.best {
            candidates.push(b);
        }
    } else {
        // Deterministic frontier: expand every decision prefix over the
        // first `depth` candidates (dropping infeasible includes and
        // dead ends), so all open nodes share idx == depth and the same
        // undecided suffix. Complete prefixes ride along untouched — the
        // worker DFS evaluates them at entry.
        struct Root {
            chosen: Vec<usize>,
            score: f64,
        }
        let n_workers = par::steal::resolve_workers(workers);
        let target = n_workers.saturating_mul(8).max(16);
        let mut frontier = vec![Root { chosen: Vec::new(), score: 0.0 }];
        let mut depth = 0usize;
        while frontier.len() < target && depth < order.len() && !frontier.is_empty() {
            let mut next = Vec::with_capacity(frontier.len() * 2);
            for node in frontier.drain(..) {
                if node.chosen.len() == inst.n {
                    next.push(node);
                    continue;
                }
                if order.len() - depth < inst.n - node.chosen.len() {
                    continue; // cannot be filled any more
                }
                let cand = order[depth];
                let mut inc_chosen = node.chosen.clone();
                inc_chosen.push(cand);
                if evaluate_view(&inst, &inc_chosen, ws).is_some() {
                    next.push(Root {
                        chosen: inc_chosen,
                        score: node.score + sorted_scores[depth],
                    });
                }
                next.push(Root { chosen: node.chosen, score: node.score });
            }
            frontier = next;
            depth += 1;
        }
        // rem at `depth` is a pure function of the depth (both branches
        // remove the candidate from the undecided set) — the SAME
        // subtraction sequence the serial DFS performs along its path
        let mut rem_at = rem_root.clone();
        for pos in 0..depth {
            let p = inst.clients[order[pos]].domain;
            rem_at[p] -= sorted_scores[pos].max(0.0);
        }
        match drain {
            BnbDrain::Serial => unreachable!(),
            BnbDrain::Chunked => {
                // fixed uniform split, kept as the bench baseline the
                // stealing drain is measured against
                let results: Vec<Option<(f64, Vec<usize>, Vec<f64>)>> =
                    par::par_ranges(frontier.len(), 1, |a, b| {
                        let mut local = BnbLocal {
                            rem_score_sum: rem_at.clone(),
                            ws: AllocWorkspace::default(),
                            best: None,
                        };
                        let mut chosen = Vec::new();
                        for node in &frontier[a..b] {
                            chosen.clear();
                            chosen.extend_from_slice(&node.chosen);
                            // save/restore-exact: rem returns to rem_at
                            // after every subtree, so one vector serves
                            // all nodes
                            bnb_dfs(&shared, &mut local, &mut chosen, node.score, depth);
                        }
                        local.best
                    });
                candidates.extend(results.into_iter().flatten());
            }
            BnbDrain::Steal => {
                // a deep subtree pins one worker; the others steal the
                // unexplored frontier nodes instead of idling at the
                // join. The shared incumbent and the strict prune make
                // the search exact under any schedule; the final
                // canonical reduction below makes the RESULT identical.
                let shared = &shared;
                let frontier = &frontier;
                let (locals, stats) = par::steal::steal_exec(
                    frontier.len(),
                    n_workers,
                    |_| {
                        (
                            BnbLocal {
                                rem_score_sum: rem_at.clone(),
                                ws: AllocWorkspace::default(),
                                best: None,
                            },
                            Vec::<usize>::new(),
                        )
                    },
                    |i, (local, chosen)| {
                        let node = &frontier[i];
                        chosen.clear();
                        chosen.extend_from_slice(&node.chosen);
                        // save/restore-exact: rem returns to rem_at
                        // after every subtree, so one vector serves all
                        // nodes this worker claims
                        bnb_dfs(shared, local, chosen, node.score, depth);
                    },
                );
                steal_stats = stats;
                candidates.extend(locals.into_iter().filter_map(|(l, _)| l.best));
            }
        }
    }

    let nodes = shared.nodes.load(Ordering::Relaxed);
    let complete = !shared.exhausted.load(Ordering::Relaxed);
    obs::add(Ctr::BnbSolves, 1);
    obs::add(Ctr::BnbNodes, nodes as u64);
    // deterministic final reduction (canonical total preference): the
    // greedy seed participates like any other candidate
    let mut best: Option<(f64, Vec<usize>, Vec<f64>)> = if seed_full {
        Some((seed.objective, seed.chosen.clone(), seed.totals.clone()))
    } else {
        None
    };
    for (obj, chosen, totals) in candidates {
        let is_better = better_solution(
            obj,
            &chosen,
            best.as_ref().map(|(o, c, _)| (*o, c.as_slice())),
        );
        if is_better {
            best = Some((obj, chosen, totals));
        }
    }
    match best {
        Some((objective, chosen, totals)) => (
            SelSolution { chosen, objective, totals, optimal: complete },
            nodes,
            steal_stats,
        ),
        None => {
            // No feasible size-n selection exists: return the (possibly
            // shorter) greedy solution, marked exact if search completed.
            let mut s = seed;
            s.optimal = complete;
            (s, nodes, steal_stats)
        }
    }
}

/// Exact branch-and-bound over an owned instance.
pub fn branch_and_bound(inst: &SelInstance, node_budget: usize) -> SelSolution {
    let vs = inst.view_storage();
    let mut ws = AllocWorkspace::default();
    branch_and_bound_view(vs.view(), node_budget, &mut ws)
}

/// Brute force over all subsets of size n (tests only; panics on big C).
pub fn enumerate(inst: &SelInstance) -> Option<SelSolution> {
    let c = inst.clients.len();
    assert!(c <= 20, "enumerate() is for tiny instances");
    let vs = inst.view_storage();
    let view = vs.view();
    let mut ws = AllocWorkspace::default();
    let mut best: Option<SelSolution> = None;
    let mut subset: Vec<usize> = Vec::new();

    fn rec(
        inst: &InstanceView<'_>,
        ws: &mut AllocWorkspace,
        start: usize,
        subset: &mut Vec<usize>,
        best: &mut Option<SelSolution>,
    ) {
        if subset.len() == inst.n {
            if let Some((obj, totals)) = evaluate_view(inst, subset, ws) {
                let better = best
                    .as_ref()
                    .map(|b| obj > b.objective + 1e-12)
                    .unwrap_or(true);
                if better {
                    *best = Some(SelSolution {
                        chosen: subset.clone(),
                        objective: obj,
                        totals,
                        optimal: true,
                    });
                }
            }
            return;
        }
        if inst.clients.len() - start < inst.n - subset.len() {
            return;
        }
        for i in start..inst.clients.len() {
            subset.push(i);
            rec(inst, ws, i + 1, subset, best);
            subset.pop();
        }
    }

    rec(&view, &mut ws, 0, &mut subset, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn random_instance(seed: u64, c_n: usize, p_n: usize, t_n: usize, n: usize) -> SelInstance {
        let mut rng = Rng::new(seed);
        let clients = (0..c_n)
            .map(|_| {
                let m_min = rng.range_f64(0.5, 2.0);
                SelClient {
                    domain: rng.below(p_n),
                    sigma: rng.range_f64(0.1, 3.0),
                    delta: rng.range_f64(0.5, 2.5),
                    m_min,
                    m_max: m_min + rng.range_f64(0.0, 6.0),
                    spare: (0..t_n)
                        .map(|_| rng.range_f64(0.0, 2.0) as f32)
                        .collect(),
                }
            })
            .collect();
        let energy = (0..p_n)
            .map(|_| {
                (0..t_n).map(|_| rng.range_f64(0.0, 5.0) as f32).collect()
            })
            .collect();
        SelInstance { n, clients, energy }
    }

    #[test]
    fn bnb_matches_enumeration() {
        let mut compared = 0;
        for seed in 0..25u64 {
            let inst = random_instance(seed, 7, 2, 4, 3);
            let exact = enumerate(&inst);
            let bnb = branch_and_bound(&inst, 1_000_000);
            match exact {
                Some(e) => {
                    assert!(bnb.optimal, "seed {seed}: budget exhausted");
                    assert_eq!(bnb.chosen.len(), inst.n, "seed {seed}");
                    assert!(
                        (e.objective - bnb.objective).abs()
                            < 1e-6 * (1.0 + e.objective),
                        "seed {seed}: enum={} bnb={}",
                        e.objective,
                        bnb.objective
                    );
                    compared += 1;
                }
                None => {
                    assert!(
                        bnb.chosen.len() < inst.n,
                        "seed {seed}: bnb found selection but enum says infeasible"
                    );
                }
            }
        }
        assert!(compared >= 10, "too few feasible instances: {compared}");
    }

    #[test]
    fn parallel_bnb_equals_serial_bnb_exactly() {
        // the tentpole invariant for the exact solver: forced-parallel
        // and forced-serial searches return the IDENTICAL selection,
        // objective (bitwise) and totals on completed searches — the
        // canonical (objective, lex) reduction is schedule-independent
        forall(25, |rng| {
            let seed = rng.next_u64();
            let c_n = rng.range(6, 16);
            let p_n = rng.range(1, 5);
            let t_n = rng.range(2, 7);
            let n = rng.range(1, 5.min(c_n));
            let inst = random_instance(seed, c_n, p_n, t_n, n);
            let vs = inst.view_storage();
            let mut ws1 = AllocWorkspace::default();
            let mut ws2 = AllocWorkspace::default();
            let (ser, _) =
                branch_and_bound_view_forced(vs.view(), 4_000_000, &mut ws1, false);
            let (par_s, _) =
                branch_and_bound_view_forced(vs.view(), 4_000_000, &mut ws2, true);
            assert!(ser.optimal && par_s.optimal, "seed {seed}: budget exhausted");
            assert_eq!(ser.chosen, par_s.chosen, "seed {seed}: chosen diverged");
            assert_eq!(
                ser.objective.to_bits(),
                par_s.objective.to_bits(),
                "seed {seed}: objective diverged ({} vs {})",
                ser.objective,
                par_s.objective
            );
            assert_eq!(ser.totals.len(), par_s.totals.len(), "seed {seed}");
            for (a, b) in ser.totals.iter().zip(&par_s.totals) {
                assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}: totals diverged");
            }
        });
    }

    /// A deliberately skewed instance: one contended domain holds most
    /// candidates with near-tied standalone scores (tie-dense → one
    /// deep frontier subtree), the rest are easy singletons. The
    /// stealing drain redistributes exactly this shape.
    fn skewed_instance(seed: u64) -> SelInstance {
        let mut rng = Rng::new(seed);
        let t_n = 4usize;
        let mut clients = Vec::new();
        for i in 0..10 {
            // contended domain 0: identical sigma/delta (exact score
            // ties), spare jittered only in the last bits
            let m_min = 1.0;
            clients.push(SelClient {
                domain: 0,
                sigma: 1.0,
                delta: 1.0,
                m_min,
                m_max: m_min + 4.0,
                spare: (0..t_n)
                    .map(|t| (1.0 + ((i + t) % 3) as f64 * 1e-6) as f32)
                    .collect(),
            });
        }
        for p in 1..4 {
            let m_min = rng.range_f64(0.5, 1.0);
            clients.push(SelClient {
                domain: p,
                sigma: rng.range_f64(0.5, 1.5),
                delta: 1.0,
                m_min,
                m_max: m_min + 3.0,
                spare: (0..t_n).map(|_| rng.range_f64(0.5, 1.5) as f32).collect(),
            });
        }
        let energy = (0..4)
            .map(|p| {
                let base = if p == 0 { 1.5 } else { 4.0 };
                (0..t_n).map(|_| base as f32).collect()
            })
            .collect();
        SelInstance { n: 4, clients, energy }
    }

    #[test]
    fn stolen_bnb_is_bitwise_identical_across_drains_and_worker_counts() {
        // skewed trees are where stealing changes the SCHEDULE the
        // most; the solution must not move a bit: Serial ≡ Chunked ≡
        // Steal at 1, 2 and 8 workers
        for seed in 0..6u64 {
            let inst = skewed_instance(seed);
            let vs = inst.view_storage();
            let mut ws = AllocWorkspace::default();
            let (reference, ref_nodes, _) = branch_and_bound_view_drained(
                vs.view(),
                4_000_000,
                &mut ws,
                BnbDrain::Serial,
                1,
            );
            assert!(reference.optimal, "seed {seed}: budget exhausted");
            assert!(
                ref_nodes > 100,
                "seed {seed}: instance too easy to exercise the drains ({ref_nodes} nodes)"
            );
            for drain in [BnbDrain::Chunked, BnbDrain::Steal] {
                for workers in [1usize, 2, 8] {
                    let mut ws = AllocWorkspace::default();
                    let (got, _, _) = branch_and_bound_view_drained(
                        vs.view(),
                        4_000_000,
                        &mut ws,
                        drain,
                        workers,
                    );
                    assert!(got.optimal, "seed {seed} {drain:?} w={workers}");
                    assert_eq!(
                        reference.chosen, got.chosen,
                        "seed {seed} {drain:?} w={workers}: chosen diverged"
                    );
                    assert_eq!(
                        reference.objective.to_bits(),
                        got.objective.to_bits(),
                        "seed {seed} {drain:?} w={workers}: objective diverged"
                    );
                    for (a, b) in reference.totals.iter().zip(&got.totals) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "seed {seed} {drain:?} w={workers}: totals diverged"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn parallel_bnb_still_matches_enumeration() {
        // the parallel path is exact, not just self-consistent
        for seed in 200..215u64 {
            let inst = random_instance(seed, 8, 3, 4, 3);
            let exact = enumerate(&inst);
            let vs = inst.view_storage();
            let mut ws = AllocWorkspace::default();
            let (bnb, _) =
                branch_and_bound_view_forced(vs.view(), 1_000_000, &mut ws, true);
            match exact {
                Some(e) => {
                    assert!(bnb.optimal, "seed {seed}: budget exhausted");
                    assert_eq!(bnb.chosen.len(), inst.n, "seed {seed}");
                    assert!(
                        (e.objective - bnb.objective).abs()
                            < 1e-6 * (1.0 + e.objective),
                        "seed {seed}: enum={} bnb={}",
                        e.objective,
                        bnb.objective
                    );
                }
                None => {
                    assert!(bnb.chosen.len() < inst.n, "seed {seed}");
                }
            }
        }
    }

    #[test]
    fn greedy_is_feasible_and_near_optimal() {
        let mut ratios = Vec::new();
        for seed in 100..130u64 {
            let inst = random_instance(seed, 8, 3, 4, 3);
            let g = greedy(&inst, 2);
            // whatever greedy chose must be feasible
            assert!(inst.evaluate(&g.chosen).is_some());
            if let Some(e) = enumerate(&inst) {
                if g.chosen.len() == inst.n && e.objective > 1e-9 {
                    ratios.push(g.objective / e.objective);
                }
            }
        }
        assert!(!ratios.is_empty());
        let worst = ratios.iter().cloned().fold(1.0, f64::min);
        let avg: f64 = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!(worst > 0.65, "worst greedy/opt ratio {worst}");
        assert!(avg > 0.9, "avg greedy/opt ratio {avg}");
    }

    #[test]
    fn greedy_respects_n() {
        let inst = random_instance(7, 12, 3, 5, 4);
        let g = greedy(&inst, 1);
        assert!(g.chosen.len() <= 4);
        let mut uniq = g.chosen.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), g.chosen.len(), "duplicate selection");
    }

    #[test]
    fn infeasible_instance_yields_partial_selection() {
        // no energy at all -> nobody can reach m_min
        let inst = SelInstance {
            n: 2,
            clients: vec![
                SelClient {
                    domain: 0,
                    sigma: 1.0,
                    delta: 1.0,
                    m_min: 1.0,
                    m_max: 5.0,
                    spare: vec![1.0; 3],
                },
                SelClient {
                    domain: 0,
                    sigma: 1.0,
                    delta: 1.0,
                    m_min: 1.0,
                    m_max: 5.0,
                    spare: vec![1.0; 3],
                },
            ],
            energy: vec![vec![0.0; 3]],
        };
        let g = greedy(&inst, 1);
        assert!(g.chosen.is_empty());
        let b = branch_and_bound(&inst, 10_000);
        assert!(b.chosen.is_empty());
    }

    #[test]
    fn shared_domain_competition_prefers_split() {
        // Two domains, each with energy for ~1 client; three candidates,
        // two of them in domain 0. Optimal picks one from each domain.
        let mk = |domain: usize, sigma: f64| SelClient {
            domain,
            sigma,
            delta: 1.0,
            m_min: 2.0,
            m_max: 4.0,
            spare: vec![2.0; 2],
        };
        let inst = SelInstance {
            n: 2,
            clients: vec![mk(0, 1.0), mk(0, 1.0), mk(1, 0.9)],
            energy: vec![vec![2.0; 2], vec![2.0; 2]],
        };
        let e = enumerate(&inst).unwrap();
        let domains: Vec<usize> =
            e.chosen.iter().map(|&i| inst.clients[i].domain).collect();
        assert!(domains.contains(&0) && domains.contains(&1), "{domains:?}");
        let g = greedy(&inst, 2);
        assert_eq!(g.chosen.len(), 2);
        assert!(
            (g.objective - e.objective).abs() < 1e-6,
            "greedy {} vs opt {}",
            g.objective,
            e.objective
        );
    }

    // ---- arena/view equivalence (satellite: solver-equivalence tests) ----

    #[test]
    fn view_greedy_matches_reference_greedy() {
        // the arena-path greedy must reproduce the retained pre-arena
        // implementation exactly: same chosen set, objective within 1e-9
        forall(40, |rng| {
            let seed = rng.next_u64();
            let c_n = rng.range(5, 40);
            let p_n = rng.range(1, 8);
            let t_n = rng.range(2, 10);
            let n = rng.range(1, 6.min(c_n));
            let inst = random_instance(seed, c_n, p_n, t_n, n);
            for passes in [0usize, 1, 2] {
                let fast = greedy(&inst, passes);
                let slow = reference_greedy(&inst, passes);
                let obj_diff = (fast.objective - slow.objective).abs();
                let scale = 1.0 + slow.objective.abs();
                assert!(
                    obj_diff < 1e-9 * scale,
                    "objective diverged (seed={seed} passes={passes}): {} vs {}",
                    fast.objective,
                    slow.objective
                );
                // identical chosen sets, except for exact ties that may
                // flip on the last-ulp difference between the singleton
                // closed form and the flow solve
                if fast.chosen != slow.chosen {
                    assert!(
                        obj_diff < 1e-12 * scale,
                        "chosen diverged beyond an exact tie (seed={seed} \
                         passes={passes}): {:?} vs {:?}",
                        fast.chosen,
                        slow.chosen
                    );
                }
            }
        });
    }

    #[test]
    fn swap_passes_never_decrease_objective() {
        forall(40, |rng| {
            let seed = rng.next_u64();
            let inst = random_instance(seed, 14, 3, 5, 4);
            let mut prev = f64::NEG_INFINITY;
            for passes in [0usize, 1, 2, 4] {
                let sol = greedy(&inst, passes);
                if sol.chosen.len() < inst.n {
                    return; // partial selections: objective not comparable
                }
                assert!(
                    sol.objective >= prev - 1e-9,
                    "seed {seed}: pass {passes} decreased objective {prev} -> {}",
                    sol.objective
                );
                prev = sol.objective;
            }
        });
    }

    #[test]
    fn singleton_domain_closed_form_matches_flow() {
        // one client alone in its domain: eval must equal the full
        // transportation solve (this is the greedy fast path)
        forall(60, |rng| {
            let seed = rng.next_u64();
            let inst = random_instance(seed, 1, 1, 6, 1);
            let c = &inst.clients[0];
            let prob = AllocProblem {
                clients: vec![AllocClient {
                    min_batches: c.m_min,
                    max_batches: c.m_max,
                    delta: c.delta,
                    weight: c.sigma,
                    spare: c.spare.clone(),
                }],
                energy: inst.energy[0].clone(),
            };
            let flow = prob.solve().map(|a| a.objective);
            let closed = {
                let sb = c.standalone_batches(&inst.energy[0]);
                if sb + 1e-6 / c.delta >= c.m_min {
                    Some(c.sigma * sb)
                } else {
                    None
                }
            };
            match (flow, closed) {
                (Some(f), Some(cl)) => assert!(
                    (f - cl).abs() < 1e-6 * (1.0 + f.abs()),
                    "seed {seed}: flow {f} vs closed form {cl}"
                ),
                (None, None) => {}
                (f, cl) => panic!(
                    "seed {seed}: feasibility mismatch flow={} closed={}",
                    f.is_some(),
                    cl.is_some()
                ),
            }
        });
    }

    /// Independent oracle for evaluate_view: the historical per-domain
    /// owned-flow evaluation (no view types, no singleton closed form).
    fn evaluate_by_flow(inst: &SelInstance, chosen: &[usize]) -> Option<(f64, Vec<f64>)> {
        let mut by_domain: Vec<Vec<usize>> = vec![Vec::new(); inst.energy.len()];
        for &i in chosen {
            by_domain[inst.clients[i].domain].push(i);
        }
        let pos: std::collections::HashMap<usize, usize> =
            chosen.iter().enumerate().map(|(k, &i)| (i, k)).collect();
        let mut objective = 0.0;
        let mut totals = vec![0.0; chosen.len()];
        for (p, mem) in by_domain.iter().enumerate() {
            if mem.is_empty() {
                continue;
            }
            let prob = AllocProblem {
                clients: mem
                    .iter()
                    .map(|&i| {
                        let c = &inst.clients[i];
                        AllocClient {
                            min_batches: c.m_min,
                            max_batches: c.m_max,
                            delta: c.delta,
                            weight: c.sigma,
                            spare: c.spare.clone(),
                        }
                    })
                    .collect(),
                energy: inst.energy[p].clone(),
            };
            let a = prob.solve()?;
            objective += a.objective;
            for (k, &i) in mem.iter().enumerate() {
                totals[pos[&i]] = a.totals[k];
            }
        }
        Some((objective, totals))
    }

    #[test]
    fn evaluate_view_matches_independent_flow_evaluation() {
        forall(30, |rng| {
            let seed = rng.next_u64();
            let inst = random_instance(seed, 12, 4, 5, 4);
            let g = greedy(&inst, 1);
            if g.chosen.is_empty() {
                return;
            }
            let flow = evaluate_by_flow(&inst, &g.chosen);
            let vs = inst.view_storage();
            let mut ws = AllocWorkspace::default();
            let viewed = evaluate_view(&vs.view(), &g.chosen, &mut ws);
            match (flow, viewed) {
                (Some((o1, t1)), Some((o2, t2))) => {
                    // singleton domains use the closed form in
                    // evaluate_view, so ulp-level differences are expected
                    assert!(
                        (o1 - o2).abs() < 1e-9 * (1.0 + o1.abs()),
                        "objective: flow {o1} vs view {o2}"
                    );
                    for (a, b) in t1.iter().zip(&t2) {
                        assert!(
                            (a - b).abs() < 1e-9 * (1.0 + a.abs()),
                            "totals: flow {a} vs view {b}"
                        );
                    }
                }
                (None, None) => {}
                _ => panic!("feasibility mismatch"),
            }
        });
    }
}
