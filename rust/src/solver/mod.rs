//! Optimization substrate (the reproduction's stand-in for Gurobi).
//!
//! The paper solves its per-round client-selection MIP with Gurobi; we
//! build the machinery from scratch:
//!
//! * [`flow`] — min-cost max-flow (successive shortest paths, f64
//!   capacities, lower-bound transformation).
//! * [`alloc`] — the per-power-domain energy/batch allocation problem for a
//!   *fixed* set of clients, solved exactly as a transportation flow after
//!   the `x = m·δ` change of variable (see DESIGN.md §2).
//! * [`lp`] — dense two-phase primal simplex, used to cross-validate the
//!   flow allocator and as a general substrate.
//! * [`mip`] — exact solvers for the selection MILP: subset enumeration
//!   (tiny instances) and branch-and-bound with admissible standalone
//!   bounds (evaluation-scale instances), with a node budget that falls
//!   back to the greedy incumbent.

pub mod alloc;
pub mod flow;
pub mod lp;
pub mod mip;
