//! Per-power-domain energy/batch allocation for a FIXED set of clients.
//!
//! This is the inner problem of the paper's selection MIP (§4.3) once the
//! binary b_c are fixed, restricted to one power domain p:
//!
//!   max  Σ_c σ_c Σ_t m_{c,t}
//!   s.t. m_min_c ≤ Σ_t m_{c,t} ≤ m_max_c          (per client)
//!        m_{c,t} ≤ spare_{c,t}                     (per client, step)
//!        Σ_c δ_c · m_{c,t} ≤ r_{p,t}               (per step)
//!
//! After the change of variable x_{c,t} = δ_c·m_{c,t} (energy instead of
//! batches) every constraint is a pure capacity, so the problem is a
//! transportation flow: source → client (bounds [δ·m_min, δ·m_max], profit
//! σ_c/δ_c per unit energy) → timestep (cap δ_c·spare) → sink (cap r_t).
//! Feasible client totals form a polymatroid, hence some profit-optimal
//! solution is volume-maximal; shifting costs to (ρ_max − ρ_c) ≥ 0 makes
//! min-cost max-flow return exactly the profit-optimal allocation. Lower
//! bounds are handled with the standard super-source/sink transformation.
//! Optimality is cross-validated against the dense simplex in tests.
//!
//! §Perf: the solver operates on borrowed slices ([`AllocClientView`])
//! so the selection hot path never clones a spare-capacity or energy
//! vector, and on a reusable [`AllocWorkspace`] so steady-state solves
//! perform no heap allocation. The owned [`AllocProblem`] /
//! [`AllocClient`] types remain as builders for tests and benches and
//! delegate to the same view-based solver.
//!
//! Forecast rows (`spare`, `energy`) are stored as `f32` — the element
//! type of the persistent forecast ring-arena (`selection::ring`), which
//! halves the 100k-client window footprint; forecasts carry far less
//! than 24 bits of real information, see the ring docs. This is the
//! solver boundary: every value is widened to f64 exactly once, where
//! arithmetic happens, so all solver layers (flow, closed forms, LP
//! cross-checks) run on identically-quantised f64 inputs.

use super::flow::{FlowNetwork, EPS};

/// One selected client within the domain.
#[derive(Clone, Debug)]
pub struct AllocClient {
    /// minimum batches it must complete if selected (m_c^min)
    pub min_batches: f64,
    /// maximum batches it may compute (m_c^max)
    pub max_batches: f64,
    /// energy per batch, Wh (δ_c)
    pub delta: f64,
    /// statistical utility weight (σ_c)
    pub weight: f64,
    /// forecast spare capacity per step, batches (m^spare_{c,t})
    pub spare: Vec<f32>,
}

/// Borrowed view of one client: identical semantics to [`AllocClient`]
/// with the spare-capacity forecast as a slice into shared storage.
#[derive(Clone, Copy, Debug)]
pub struct AllocClientView<'a> {
    pub min_batches: f64,
    pub max_batches: f64,
    pub delta: f64,
    pub weight: f64,
    pub spare: &'a [f32],
}

impl AllocClient {
    pub fn view(&self) -> AllocClientView<'_> {
        AllocClientView {
            min_batches: self.min_batches,
            max_batches: self.max_batches,
            delta: self.delta,
            weight: self.weight,
            spare: &self.spare,
        }
    }
}

/// The allocation instance for one power domain over `T` timesteps.
#[derive(Clone, Debug, Default)]
pub struct AllocProblem {
    pub clients: Vec<AllocClient>,
    /// excess energy forecast per step, Wh (r_{p,t})
    pub energy: Vec<f32>,
}

/// Optimal allocation (batches per client per step).
#[derive(Clone, Debug)]
pub struct Allocation {
    pub batches: Vec<Vec<f64>>,
    /// Σ_t batches per client
    pub totals: Vec<f64>,
    /// Σ_c σ_c · totals_c
    pub objective: f64,
}

/// Reusable scratch for the flow solver: the network (with its internal
/// SPFA buffers) plus the schedule-arc id table. One workspace serves an
/// arbitrary sequence of solves of any shape.
#[derive(Debug, Default)]
pub struct AllocWorkspace {
    net: FlowNetwork,
    /// c→t arc ids, flattened [c_n × t_n]
    sched_arcs: Vec<usize>,
}

/// Build the transportation network for `clients`/`energy` into `ws` and
/// run both flow phases. Returns `false` iff the joint m_min lower bounds
/// are infeasible. Arc construction order is identical to the historical
/// owned solver, so results are bit-for-bit reproducible.
fn build_and_run(
    clients: &[AllocClientView<'_>],
    energy: &[f32],
    ws: &mut AllocWorkspace,
) -> bool {
    let c_n = clients.len();
    let t_n = energy.len();
    for c in clients {
        assert!(c.delta > 0.0, "delta must be positive");
        assert!(c.spare.len() == t_n, "spare horizon mismatch");
        assert!(c.max_batches >= c.min_batches - EPS);
    }

    // profit per unit energy; shift so all arc costs are >= 0
    let rho_max = clients
        .iter()
        .map(|c| c.weight / c.delta)
        .fold(0.0, f64::max);

    // node layout
    let s = 0;
    let t = 1;
    let ss = 2;
    let tt = 3;
    let client_node = |i: usize| 4 + i;
    let time_node = |j: usize| 4 + c_n + j;
    ws.net.reset(4 + c_n + t_n);
    ws.sched_arcs.clear();

    let total_energy: f64 = energy.iter().map(|&e| e as f64).sum();
    let mut lb_total = 0.0;
    for (i, c) in clients.iter().enumerate() {
        let lb = c.delta * c.min_batches;
        let ub = c.delta * c.max_batches;
        lb_total += lb;
        // optional energy above the minimum, profit-bearing
        ws.net
            .add_edge(s, client_node(i), ub - lb, rho_max - c.weight / c.delta);
        // mandatory minimum via the super-source
        ws.net.add_edge(ss, client_node(i), lb, 0.0);
        for j in 0..t_n {
            let cap = c.delta * c.spare[j] as f64;
            let id = ws.net.add_edge(client_node(i), time_node(j), cap, 0.0);
            ws.sched_arcs.push(id);
        }
    }
    for (j, &r) in energy.iter().enumerate() {
        ws.net.add_edge(time_node(j), t, r as f64, 0.0);
    }
    // circulation return + deficit sink for the lower-bound transform
    ws.net.add_edge(t, s, total_energy + lb_total + 1.0, 0.0);
    ws.net.add_edge(s, tt, lb_total, 0.0);

    // Phase 1: route every mandatory minimum. Saturation == feasible.
    let (feas_flow, _) = ws.net.min_cost_max_flow(ss, tt, f64::INFINITY);
    if feas_flow + 1e-6 < lb_total {
        return false;
    }
    // Phase 2: profit-optimal augmentation of the optional energy.
    let _ = ws.net.min_cost_max_flow(s, t, f64::INFINITY);
    true
}

/// Exact solve returning only the objective Σ_c σ_c·totals_c; `None` iff
/// infeasible. Allocation-free at steady state — this is the call the
/// greedy insertion/swap loops make thousands of times per selection.
pub fn solve_objective(
    clients: &[AllocClientView<'_>],
    energy: &[f32],
    ws: &mut AllocWorkspace,
) -> Option<f64> {
    if clients.is_empty() {
        return Some(0.0);
    }
    if !build_and_run(clients, energy, ws) {
        return None;
    }
    let t_n = energy.len();
    let mut objective = 0.0;
    for (i, c) in clients.iter().enumerate() {
        let mut total = 0.0;
        for j in 0..t_n {
            total += ws.net.flow_on(ws.sched_arcs[i * t_n + j]) / c.delta;
        }
        objective += c.weight * total;
    }
    Some(objective)
}

/// Exact solve with the full per-step schedule; `None` iff the m_min
/// lower bounds are jointly infeasible under the energy/spare caps.
pub fn solve_full(
    clients: &[AllocClientView<'_>],
    energy: &[f32],
    ws: &mut AllocWorkspace,
) -> Option<Allocation> {
    if clients.is_empty() {
        return Some(Allocation {
            batches: Vec::new(),
            totals: Vec::new(),
            objective: 0.0,
        });
    }
    if !build_and_run(clients, energy, ws) {
        return None;
    }
    let c_n = clients.len();
    let t_n = energy.len();
    let mut batches = vec![vec![0.0; t_n]; c_n];
    let mut totals = vec![0.0; c_n];
    for (i, c) in clients.iter().enumerate() {
        for j in 0..t_n {
            let b = ws.net.flow_on(ws.sched_arcs[i * t_n + j]) / c.delta;
            batches[i][j] = b;
            totals[i] += b;
        }
    }
    let objective = clients
        .iter()
        .zip(&totals)
        .map(|(c, &tot)| c.weight * tot)
        .sum();
    Some(Allocation { batches, totals, objective })
}

/// Max batches a SINGLE client could compute if it had the domain's
/// entire energy to itself (the paper's Algorithm-1 line-11 filter, the
/// admissible bound used by branch-and-bound, and — because a singleton
/// domain's exact optimum IS its standalone value — the closed form the
/// greedy solver uses to skip flow solves on one-member domains).
pub fn standalone_batches_view(
    spare: &[f32],
    delta: f64,
    max_batches: f64,
    energy: &[f32],
) -> f64 {
    let raw: f64 = spare
        .iter()
        .zip(energy)
        .map(|(&sp, &r)| (sp as f64).min(r as f64 / delta))
        .sum();
    raw.min(max_batches)
}

impl AllocProblem {
    /// Exact solve; `None` iff the m_min lower bounds are jointly
    /// infeasible under the energy/spare caps.
    pub fn solve(&self) -> Option<Allocation> {
        let views: Vec<AllocClientView<'_>> =
            self.clients.iter().map(|c| c.view()).collect();
        let mut ws = AllocWorkspace::default();
        solve_full(&views, &self.energy, &mut ws)
    }

    /// Max batches a SINGLE client could compute with the whole domain
    /// budget (see [`standalone_batches_view`]).
    pub fn standalone_batches(client: &AllocClient, energy: &[f32]) -> f64 {
        standalone_batches_view(
            &client.spare,
            client.delta,
            client.max_batches,
            energy,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn client(min: f64, max: f64, delta: f64, w: f64, spare: &[f32]) -> AllocClient {
        AllocClient {
            min_batches: min,
            max_batches: max,
            delta,
            weight: w,
            spare: spare.to_vec(),
        }
    }

    fn check_valid(p: &AllocProblem, a: &Allocation) {
        for (i, c) in p.clients.iter().enumerate() {
            assert!(
                a.totals[i] >= c.min_batches - 1e-6,
                "client {i} below min: {} < {}",
                a.totals[i],
                c.min_batches
            );
            assert!(a.totals[i] <= c.max_batches + 1e-6);
            for (j, &b) in a.batches[i].iter().enumerate() {
                assert!(b >= -1e-9);
                assert!(b <= c.spare[j] as f64 + 1e-6, "spare violated");
            }
        }
        for j in 0..p.energy.len() {
            let used: f64 = p
                .clients
                .iter()
                .enumerate()
                .map(|(i, c)| a.batches[i][j] * c.delta)
                .sum();
            assert!(
                used <= p.energy[j] as f64 + 1e-6,
                "energy budget violated at {j}"
            );
        }
    }

    #[test]
    fn single_client_unconstrained_energy() {
        let p = AllocProblem {
            clients: vec![client(2.0, 10.0, 1.0, 1.0, &[4.0, 4.0, 4.0])],
            energy: vec![100.0, 100.0, 100.0],
        };
        let a = p.solve().unwrap();
        check_valid(&p, &a);
        // spare-limited: 12 possible but capped at max=10
        assert!((a.totals[0] - 10.0).abs() < 1e-6, "{:?}", a.totals);
    }

    #[test]
    fn energy_limits_batches() {
        // delta=2 Wh/batch, 3 Wh per step => 1.5 batches/step max by energy
        let p = AllocProblem {
            clients: vec![client(1.0, 100.0, 2.0, 1.0, &[10.0, 10.0])],
            energy: vec![3.0, 3.0],
        };
        let a = p.solve().unwrap();
        check_valid(&p, &a);
        assert!((a.totals[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_minimum_returns_none() {
        let p = AllocProblem {
            clients: vec![client(5.0, 10.0, 1.0, 1.0, &[1.0, 1.0])],
            energy: vec![100.0, 100.0],
        };
        assert!(p.solve().is_none());
    }

    #[test]
    fn shared_energy_prefers_high_weight_client() {
        // Two identical clients, one with 3x the utility weight. Energy only
        // allows ~one of them beyond the minimum.
        let p = AllocProblem {
            clients: vec![
                client(1.0, 10.0, 1.0, 1.0, &[5.0, 5.0]),
                client(1.0, 10.0, 1.0, 3.0, &[5.0, 5.0]),
            ],
            energy: vec![6.0, 6.0],
        };
        let a = p.solve().unwrap();
        check_valid(&p, &a);
        // total energy 12, minimums take 2, the remaining 10 should go to
        // client 1 (weight 3) up to its caps: totals = [2, 10]
        assert!((a.totals[1] - 10.0).abs() < 1e-6, "{:?}", a.totals);
        assert!((a.totals[0] - 2.0).abs() < 1e-6, "{:?}", a.totals);
        assert!((a.objective - (2.0 + 30.0)).abs() < 1e-6);
    }

    #[test]
    fn minimum_forces_low_weight_client_to_run() {
        // high-weight client could eat everything, but the low-weight one
        // has a hard minimum that must be honoured.
        let p = AllocProblem {
            clients: vec![
                client(4.0, 10.0, 1.0, 0.1, &[5.0, 5.0]),
                client(0.0, 10.0, 1.0, 9.0, &[5.0, 5.0]),
            ],
            energy: vec![5.0, 5.0],
        };
        let a = p.solve().unwrap();
        check_valid(&p, &a);
        assert!(a.totals[0] >= 4.0 - 1e-6);
        assert!((a.totals[0] + a.totals[1] - 10.0).abs() < 1e-6);
        assert!((a.totals[1] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn heterogeneous_efficiency_favors_efficient_client() {
        // same utility, client 0 needs 1 Wh/batch, client 1 needs 4 Wh/batch:
        // per-Wh profit is 4x higher for client 0.
        let p = AllocProblem {
            clients: vec![
                client(0.0, 100.0, 1.0, 1.0, &[3.0; 4]),
                client(0.0, 100.0, 4.0, 1.0, &[3.0; 4]),
            ],
            energy: vec![4.0; 4],
        };
        let a = p.solve().unwrap();
        check_valid(&p, &a);
        // client 0 takes 3 batches/step (spare cap, 3 Wh), leftover 1 Wh/step
        // gives client 1 a 0.25 batch/step.
        assert!((a.totals[0] - 12.0).abs() < 1e-6, "{:?}", a.totals);
        assert!((a.totals[1] - 1.0).abs() < 1e-6, "{:?}", a.totals);
    }

    #[test]
    fn empty_problem() {
        let p = AllocProblem { clients: vec![], energy: vec![1.0] };
        let a = p.solve().unwrap();
        assert_eq!(a.objective, 0.0);
    }

    #[test]
    fn standalone_matches_manual() {
        let c = client(1.0, 7.0, 2.0, 1.0, &[4.0, 4.0, 0.5]);
        // per-step: min(4, r/2): r = [4, 100, 100] -> [2, 4, 0.5] = 6.5
        let b = AllocProblem::standalone_batches(&c, &[4.0, 100.0, 100.0]);
        assert!((b - 6.5).abs() < 1e-9);
        // cap at max_batches
        let b2 = AllocProblem::standalone_batches(&c, &[100.0, 100.0, 100.0]);
        assert!((b2 - 7.0).abs() < 1e-9);
    }

    #[test]
    fn workspace_reuse_is_bit_identical_to_fresh_solves() {
        // the same workspace drives differently-shaped problems in
        // sequence; every answer must equal a fresh-workspace solve
        let probs = vec![
            AllocProblem {
                clients: vec![
                    client(1.0, 10.0, 1.0, 1.0, &[5.0, 5.0]),
                    client(1.0, 10.0, 1.0, 3.0, &[5.0, 5.0]),
                ],
                energy: vec![6.0, 6.0],
            },
            AllocProblem {
                clients: vec![client(2.0, 10.0, 1.0, 1.0, &[4.0, 4.0, 4.0])],
                energy: vec![100.0, 100.0, 100.0],
            },
            AllocProblem {
                clients: vec![client(5.0, 10.0, 1.0, 1.0, &[1.0, 1.0])],
                energy: vec![100.0, 100.0],
            },
        ];
        let mut ws = AllocWorkspace::default();
        for p in &probs {
            let views: Vec<AllocClientView<'_>> =
                p.clients.iter().map(|c| c.view()).collect();
            let shared = solve_full(&views, &p.energy, &mut ws);
            let fresh = p.solve();
            match (shared, fresh) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.totals, b.totals);
                    assert_eq!(a.objective, b.objective);
                }
                (None, None) => {}
                (a, b) => panic!(
                    "feasibility mismatch: shared={} fresh={}",
                    a.is_some(),
                    b.is_some()
                ),
            }
        }
    }

    #[test]
    fn objective_only_matches_full_solve() {
        let p = AllocProblem {
            clients: vec![
                client(1.0, 10.0, 1.0, 1.0, &[5.0, 5.0]),
                client(1.0, 10.0, 1.0, 3.0, &[5.0, 5.0]),
                client(0.5, 4.0, 2.0, 0.7, &[2.0, 2.0]),
            ],
            energy: vec![6.0, 6.0],
        };
        let views: Vec<AllocClientView<'_>> =
            p.clients.iter().map(|c| c.view()).collect();
        let mut ws = AllocWorkspace::default();
        let obj = solve_objective(&views, &p.energy, &mut ws).unwrap();
        let full = p.solve().unwrap();
        assert_eq!(obj, full.objective);
    }
}
