//! Dense two-phase primal simplex (the general-purpose LP substrate).
//!
//! Supports `maximize c·x` over `x ≥ 0` with arbitrary ≤ / ≥ / = rows.
//! Bland's rule everywhere, so cycling is impossible (at the cost of speed —
//! this solver exists for correctness cross-checks of the flow allocator,
//! for the exact-MIP relaxations in tests, and as a substrate; the hot
//! selection path uses [`super::alloc`]).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

#[derive(Clone, Debug)]
pub struct Lp {
    /// number of structural variables
    pub n: usize,
    /// objective coefficients (maximization)
    pub objective: Vec<f64>,
    /// rows: (coefficients, comparator, rhs)
    pub rows: Vec<(Vec<f64>, Cmp, f64)>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, value: f64 },
    Infeasible,
    Unbounded,
}

const EPS: f64 = 1e-9;

impl Lp {
    pub fn new(n: usize) -> Self {
        Lp { n, objective: vec![0.0; n], rows: Vec::new() }
    }

    pub fn maximize(mut self, c: &[f64]) -> Self {
        assert_eq!(c.len(), self.n);
        self.objective = c.to_vec();
        self
    }

    pub fn constrain(&mut self, coeffs: &[f64], cmp: Cmp, rhs: f64) {
        assert_eq!(coeffs.len(), self.n);
        self.rows.push((coeffs.to_vec(), cmp, rhs));
    }

    /// Convenience: `x[i] <= ub`.
    pub fn upper_bound(&mut self, i: usize, ub: f64) {
        let mut c = vec![0.0; self.n];
        c[i] = 1.0;
        self.constrain(&c, Cmp::Le, ub);
    }

    pub fn solve(&self) -> LpResult {
        // Normalise to rhs >= 0 (flip rows), then add slack/surplus and
        // artificial variables.
        let m = self.rows.len();
        let mut rows: Vec<(Vec<f64>, Cmp, f64)> = self.rows.clone();
        for (coeffs, cmp, rhs) in rows.iter_mut() {
            if *rhs < 0.0 {
                for c in coeffs.iter_mut() {
                    *c = -*c;
                }
                *rhs = -*rhs;
                *cmp = match *cmp {
                    Cmp::Le => Cmp::Ge,
                    Cmp::Ge => Cmp::Le,
                    Cmp::Eq => Cmp::Eq,
                };
            }
        }

        // column layout: [structural | slack/surplus | artificial | rhs]
        let mut n_slack = 0;
        let mut n_art = 0;
        for (_, cmp, _) in &rows {
            match cmp {
                Cmp::Le => n_slack += 1,
                Cmp::Ge => {
                    n_slack += 1;
                    n_art += 1;
                }
                Cmp::Eq => n_art += 1,
            }
        }
        let total = self.n + n_slack + n_art;
        let rhs_col = total;
        let mut t = vec![vec![0.0; total + 1]; m];
        let mut basis = vec![0usize; m];
        let mut art_cols = Vec::new();
        let mut slack_i = self.n;
        let mut art_i = self.n + n_slack;
        for (r, (coeffs, cmp, rhs)) in rows.iter().enumerate() {
            t[r][..self.n].copy_from_slice(coeffs);
            t[r][rhs_col] = *rhs;
            match cmp {
                Cmp::Le => {
                    t[r][slack_i] = 1.0;
                    basis[r] = slack_i;
                    slack_i += 1;
                }
                Cmp::Ge => {
                    t[r][slack_i] = -1.0;
                    slack_i += 1;
                    t[r][art_i] = 1.0;
                    basis[r] = art_i;
                    art_cols.push(art_i);
                    art_i += 1;
                }
                Cmp::Eq => {
                    t[r][art_i] = 1.0;
                    basis[r] = art_i;
                    art_cols.push(art_i);
                    art_i += 1;
                }
            }
        }

        // Phase 1: minimise sum of artificials (maximize -sum).
        if n_art > 0 {
            let mut obj = vec![0.0; total + 1];
            for &c in &art_cols {
                obj[c] = -1.0;
            }
            // price out the basic artificials
            for r in 0..m {
                if art_cols.contains(&basis[r]) {
                    for c in 0..=total {
                        obj[c] += t[r][c];
                    }
                }
            }
            if !simplex_iterate(&mut t, &mut obj, &mut basis, total, rhs_col) {
                return LpResult::Unbounded; // cannot happen in phase 1
            }
            if obj[rhs_col] > 1e-7 {
                return LpResult::Infeasible;
            }
            // Drive any artificial still in the basis out (degenerate rows).
            for r in 0..m {
                if art_cols.contains(&basis[r]) {
                    let pivot_col = (0..self.n + n_slack)
                        .find(|&c| t[r][c].abs() > EPS);
                    if let Some(c) = pivot_col {
                        pivot(&mut t, &mut basis, r, c, rhs_col);
                    }
                    // else: zero row, harmless
                }
            }
        }

        // Phase 2: original objective, artificial columns frozen at zero.
        let mut obj = vec![0.0; total + 1];
        obj[..self.n].copy_from_slice(&self.objective);
        for &c in &art_cols {
            obj[c] = f64::NEG_INFINITY; // never re-enter
        }
        // price out current basis
        for r in 0..m {
            let b = basis[r];
            if obj[b].abs() > EPS && obj[b].is_finite() {
                let coef = obj[b];
                for c in 0..=total {
                    if obj[c].is_finite() {
                        obj[c] -= coef * t[r][c];
                    }
                }
                obj[b] = 0.0;
            }
        }
        if !simplex_iterate(&mut t, &mut obj, &mut basis, total, rhs_col) {
            return LpResult::Unbounded;
        }

        let mut x = vec![0.0; self.n];
        for r in 0..m {
            if basis[r] < self.n {
                x[basis[r]] = t[r][rhs_col];
            }
        }
        let value: f64 = self
            .objective
            .iter()
            .zip(&x)
            .map(|(c, v)| c * v)
            .sum();
        LpResult::Optimal { x, value }
    }
}

/// Run primal simplex iterations in place. Returns false on unboundedness.
/// `obj` holds reduced costs for a MAXIMIZATION: enter while any positive.
fn simplex_iterate(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    total: usize,
    rhs_col: usize,
) -> bool {
    let m = t.len();
    loop {
        // Bland: smallest-index column with positive reduced cost
        let Some(col) = (0..total)
            .find(|&c| obj[c].is_finite() && obj[c] > 1e-7)
        else {
            return true;
        };
        // ratio test, Bland tie-break on basis index
        let mut best: Option<(f64, usize)> = None;
        for r in 0..m {
            if t[r][col] > EPS {
                let ratio = t[r][rhs_col] / t[r][col];
                match best {
                    None => best = Some((ratio, r)),
                    Some((br, brow)) => {
                        if ratio < br - EPS
                            || (ratio < br + EPS && basis[r] < basis[brow])
                        {
                            best = Some((ratio, r));
                        }
                    }
                }
            }
        }
        let Some((_, row)) = best else {
            return false; // unbounded
        };
        pivot_with_obj(t, obj, basis, row, col, rhs_col);
    }
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, rhs_col: usize) {
    let m = t.len();
    let p = t[row][col];
    for c in 0..=rhs_col {
        t[row][c] /= p;
    }
    for r in 0..m {
        if r != row && t[r][col].abs() > EPS {
            let f = t[r][col];
            for c in 0..=rhs_col {
                t[r][c] -= f * t[row][c];
            }
        }
    }
    basis[row] = col;
}

fn pivot_with_obj(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    row: usize,
    col: usize,
    rhs_col: usize,
) {
    pivot(t, basis, row, col, rhs_col);
    if obj[col].abs() > 0.0 && obj[col].is_finite() {
        let f = obj[col];
        for c in 0..=rhs_col {
            if obj[c].is_finite() {
                obj[c] -= f * t[row][c];
            }
        }
        obj[col] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solve_x(lp: &Lp) -> (Vec<f64>, f64) {
        match lp.solve() {
            LpResult::Optimal { x, value } => (x, value),
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn textbook_le_problem() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 -> (2, 6), 36
        let mut lp = Lp::new(2).maximize(&[3.0, 5.0]);
        lp.constrain(&[1.0, 0.0], Cmp::Le, 4.0);
        lp.constrain(&[0.0, 2.0], Cmp::Le, 12.0);
        lp.constrain(&[3.0, 2.0], Cmp::Le, 18.0);
        let (x, v) = solve_x(&lp);
        assert!((v - 36.0).abs() < 1e-6);
        assert!((x[0] - 2.0).abs() < 1e-6 && (x[1] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn equality_and_ge_rows() {
        // max x + y s.t. x + y = 10, x >= 3, y <= 4 -> (6, 4) value 10
        let mut lp = Lp::new(2).maximize(&[1.0, 1.0]);
        lp.constrain(&[1.0, 1.0], Cmp::Eq, 10.0);
        lp.constrain(&[1.0, 0.0], Cmp::Ge, 3.0);
        lp.constrain(&[0.0, 1.0], Cmp::Le, 4.0);
        let (x, v) = solve_x(&lp);
        assert!((v - 10.0).abs() < 1e-6);
        assert!(x[0] >= 3.0 - 1e-6 && x[1] <= 4.0 + 1e-6);
    }

    #[test]
    fn detects_infeasible() {
        let mut lp = Lp::new(1).maximize(&[1.0]);
        lp.constrain(&[1.0], Cmp::Ge, 5.0);
        lp.constrain(&[1.0], Cmp::Le, 3.0);
        assert_eq!(lp.solve(), LpResult::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let mut lp = Lp::new(2).maximize(&[1.0, 0.0]);
        lp.constrain(&[0.0, 1.0], Cmp::Le, 1.0);
        assert_eq!(lp.solve(), LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_normalisation() {
        // max -x s.t. -x <= -2  (i.e. x >= 2) -> x = 2
        let mut lp = Lp::new(1).maximize(&[-1.0]);
        lp.constrain(&[-1.0], Cmp::Le, -2.0);
        let (x, v) = solve_x(&lp);
        assert!((x[0] - 2.0).abs() < 1e-6);
        assert!((v + 2.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // classic degeneracy stressor
        let mut lp = Lp::new(4).maximize(&[0.75, -150.0, 0.02, -6.0]);
        lp.constrain(&[0.25, -60.0, -0.04, 9.0], Cmp::Le, 0.0);
        lp.constrain(&[0.5, -90.0, -0.02, 3.0], Cmp::Le, 0.0);
        lp.constrain(&[0.0, 0.0, 1.0, 0.0], Cmp::Le, 1.0);
        let (_, v) = solve_x(&lp);
        assert!((v - 0.05).abs() < 1e-6, "v={v}");
    }

    #[test]
    fn matches_flow_allocator_on_random_instances() {
        // Cross-validation: the flow allocator must equal the LP optimum of
        // the same per-domain allocation problem.
        use crate::solver::alloc::{AllocClient, AllocProblem};
        use crate::util::rng::Rng;
        for seed in 0..30u64 {
            let mut rng = Rng::new(seed);
            let c_n = rng.range(1, 4);
            let t_n = rng.range(1, 5);
            let clients: Vec<AllocClient> = (0..c_n)
                .map(|_| {
                    let max = rng.range_f64(1.0, 6.0);
                    AllocClient {
                        min_batches: rng.range_f64(0.0, 1.0),
                        max_batches: max,
                        delta: rng.range_f64(0.5, 3.0),
                        weight: rng.range_f64(0.1, 5.0),
                        spare: (0..t_n)
                            .map(|_| rng.range_f64(0.0, 3.0) as f32)
                            .collect(),
                    }
                })
                .collect();
            // f32 like the forecast arena; the LP below reads the same
            // quantised values so both solvers see identical instances
            let energy: Vec<f32> =
                (0..t_n).map(|_| rng.range_f64(0.0, 6.0) as f32).collect();
            let prob = AllocProblem { clients: clients.clone(), energy: energy.clone() };

            // LP formulation over m_{c,t}
            let nv = c_n * t_n;
            let mut obj = vec![0.0; nv];
            for i in 0..c_n {
                for j in 0..t_n {
                    obj[i * t_n + j] = clients[i].weight;
                }
            }
            let mut lp = Lp::new(nv).maximize(&obj);
            for i in 0..c_n {
                let mut row = vec![0.0; nv];
                for j in 0..t_n {
                    row[i * t_n + j] = 1.0;
                }
                lp.constrain(&row, Cmp::Ge, clients[i].min_batches);
                lp.constrain(&row, Cmp::Le, clients[i].max_batches);
                for j in 0..t_n {
                    lp.upper_bound(i * t_n + j, clients[i].spare[j] as f64);
                }
            }
            for j in 0..t_n {
                let mut row = vec![0.0; nv];
                for i in 0..c_n {
                    row[i * t_n + j] = clients[i].delta;
                }
                lp.constrain(&row, Cmp::Le, energy[j] as f64);
            }

            let flow_result = prob.solve();
            match (lp.solve(), flow_result) {
                (LpResult::Infeasible, None) => {}
                (LpResult::Optimal { value, .. }, Some(a)) => {
                    assert!(
                        (value - a.objective).abs()
                            < 1e-5 * (1.0 + value.abs()),
                        "seed {seed}: lp={value} flow={}",
                        a.objective
                    );
                }
                (lp_r, flow_r) => panic!(
                    "seed {seed}: feasibility disagreement lp={lp_r:?} flow={flow_r:?}"
                ),
            }
        }
    }
}
