//! Scenario configuration: builds the paper's two evaluation scenarios
//! (§5.1) — 100 clients over 10 solar power domains, global (ten cities
//! worldwide, June) or co-located (ten German cities, July) — plus the
//! Berlin-unlimited variant of Fig 6b / Table 4.

use crate::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
use crate::data::Partition;
use crate::energy::PowerDomain;
use crate::trace::forecast::{ErrorLevel, SeriesForecaster};
use crate::trace::load::{plan_forecast, LoadModel};
use crate::trace::solar;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    Global,
    Colocated,
}

impl Scenario {
    pub fn name(self) -> &'static str {
        match self {
            Scenario::Global => "global",
            Scenario::Colocated => "co-located",
        }
    }

    pub fn sites(self) -> Vec<solar::Site> {
        match self {
            Scenario::Global => solar::global_sites(),
            Scenario::Colocated => solar::colocated_sites(),
        }
    }

    /// paper dates: June 8 (global) / July 15 (co-located)
    pub fn start_day_of_year(self) -> u32 {
        match self {
            Scenario::Global => 159,
            Scenario::Colocated => 196,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    pub scenario: Scenario,
    pub n_clients: usize,
    pub days: usize,
    pub step_minutes: f64,
    /// max output per power domain (paper: 800 W)
    pub domain_capacity_w: f64,
    pub energy_error: ErrorLevel,
    pub load_error: ErrorLevel,
    /// give this domain unlimited energy + its clients unlimited capacity
    pub unlimited_domain: Option<usize>,
    pub seed: u64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            scenario: Scenario::Global,
            n_clients: 100,
            days: 7,
            step_minutes: 1.0,
            domain_capacity_w: 800.0,
            energy_error: ErrorLevel::Realistic,
            load_error: ErrorLevel::Realistic,
            unlimited_domain: None,
            seed: 0,
        }
    }
}

/// Everything the simulator needs about the environment. `Clone` so the
/// campaign runner (`crate::scenario::campaign`) can memoize one build
/// per (environment, seed) and hand cells cheap copies instead of
/// regenerating the traces.
#[derive(Clone)]
pub struct BuiltScenario {
    pub clients: Vec<ClientInfo>,
    pub domains: Vec<PowerDomain>,
    /// actual utilisation per client per step
    pub load_actual: Vec<Vec<f64>>,
    /// spare-capacity forecasters (batches/step series)
    pub load_fc: Vec<SeriesForecaster>,
    /// per-client outage windows `[start, end)` in steps (empty inner
    /// vec = always online) from the scenario churn model; the engine
    /// grants an offline client neither energy nor batches. The legacy
    /// paper scenarios have no churn, so [`build`] leaves every client
    /// fully available.
    pub outages: Vec<Vec<(usize, usize)>>,
    pub horizon: usize,
}

impl BuiltScenario {
    pub fn client_domains(&self) -> Vec<usize> {
        self.clients.iter().map(|c| c.domain).collect()
    }
}

/// Build clients/domains/traces. `partition` provides each client's data
/// shard (and thereby m_min/m_max); `model` picks the Table-2 column.
///
/// This is the LEGACY enum-driven path, retained verbatim as the
/// bit-equivalence oracle for the declarative scenario engine: the
/// builtin specs of [`crate::scenario`] must reproduce this function's
/// output exactly — same RNG call sequence, same float arithmetic —
/// which `scenario::tests` and `benches/campaign.rs` gate on. The
/// coordinator now routes every experiment through
/// [`crate::scenario::build_env`]; do not change this function and the
/// spec-driven builder independently.
pub fn build(
    cfg: &ScenarioConfig,
    model: ModelKind,
    batch_size: usize,
    partition: &Partition,
) -> BuiltScenario {
    assert_eq!(partition.clients.len(), cfg.n_clients);
    let mut rng = Rng::new(cfg.seed ^ 0xC0FFEE);
    let horizon = (cfg.days as f64 * 24.0 * 60.0 / cfg.step_minutes) as usize;
    let sites = cfg.scenario.sites();
    let n_domains = sites.len();
    let start_day = cfg.scenario.start_day_of_year();

    // --- power domains -----------------------------------------------------
    let regional = match cfg.scenario {
        Scenario::Colocated => Some(solar::regional_cloud_series(
            horizon,
            cfg.step_minutes,
            0.4,
            &mut rng.fork(0xC10D),
        )),
        Scenario::Global => None,
    };
    let mut domains: Vec<PowerDomain> = sites
        .iter()
        .enumerate()
        .map(|(i, site)| {
            let mut site_rng = rng.fork(0x50 + i as u64);
            let power = solar::generate(
                site,
                cfg.domain_capacity_w,
                start_day,
                horizon,
                cfg.step_minutes,
                &mut site_rng,
                regional.as_deref(),
            );
            let forecaster = match cfg.energy_error {
                ErrorLevel::Perfect => SeriesForecaster::perfect(power.clone()),
                _ => SeriesForecaster::realistic(
                    power.clone(),
                    cfg.seed ^ (i as u64) << 8,
                    60.0 / cfg.step_minutes,
                ),
            };
            PowerDomain::new(
                i,
                &site.name,
                cfg.domain_capacity_w,
                power,
                forecaster,
                cfg.step_minutes,
            )
        })
        .collect();
    if let Some(u) = cfg.unlimited_domain {
        domains[u].unlimited = true;
    }

    // --- clients ------------------------------------------------------------
    let mut clients = Vec::with_capacity(cfg.n_clients);
    let mut load_actual = Vec::with_capacity(cfg.n_clients);
    let mut load_fc = Vec::with_capacity(cfg.n_clients);
    for i in 0..cfg.n_clients {
        let domain = rng.below(n_domains);
        let device = DeviceType::sample(&mut rng);
        let profile =
            ClientProfile::new(device, model, batch_size, cfg.step_minutes);
        let info = ClientInfo::new(
            i,
            domain,
            profile,
            partition.clients[i].clone(),
            batch_size,
        );

        let unlimited_client = cfg.unlimited_domain == Some(domain);
        let mut load_rng = rng.fork(0x10AD + i as u64);
        let util: Vec<f64> = if unlimited_client {
            vec![0.0; horizon] // unlimited computing resources (Fig 6b)
        } else {
            LoadModel::sample(&mut load_rng, sites[domain].utc_offset_h)
                .generate(horizon, cfg.step_minutes, &mut load_rng)
        };
        // spare series in batches/step
        let cap = info.capacity();
        let spare: Vec<f64> = util.iter().map(|&u| cap * (1.0 - u)).collect();
        let fc = match cfg.load_error {
            ErrorLevel::Perfect => SeriesForecaster::perfect(spare.clone()),
            _ => {
                // gpu_plan-style: hourly-mean plan as the forecast basis
                let plan = plan_forecast(&spare, cfg.step_minutes);
                SeriesForecaster::perfect(plan)
            }
        };
        clients.push(info);
        load_actual.push(util);
        load_fc.push(fc);
    }

    let outages = vec![Vec::new(); cfg.n_clients];
    BuiltScenario { clients, domains, load_actual, load_fc, outages, horizon }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::dirichlet_partition;

    fn quick_partition(n_clients: usize, rng: &mut Rng) -> Partition {
        let labels: Vec<i32> = (0..2000).map(|i| (i % 10) as i32).collect();
        dirichlet_partition(&labels, n_clients, 0.5, rng)
    }

    #[test]
    fn builds_paper_scale_scenario() {
        let mut rng = Rng::new(1);
        let part = quick_partition(100, &mut rng);
        let cfg = ScenarioConfig { days: 1, ..Default::default() };
        let b = build(&cfg, ModelKind::Vision, 10, &part);
        assert_eq!(b.clients.len(), 100);
        assert_eq!(b.domains.len(), 10);
        assert_eq!(b.horizon, 1440);
        assert_eq!(b.load_actual.len(), 100);
        // all domains referenced
        let doms = b.client_domains();
        assert!(doms.iter().all(|&d| d < 10));
        // device types are mixed
        let smalls = b
            .clients
            .iter()
            .filter(|c| c.profile.device == DeviceType::Small)
            .count();
        assert!(smalls > 10 && smalls < 60, "smalls={smalls}");
    }

    #[test]
    fn colocated_domains_share_daylight() {
        let mut rng = Rng::new(2);
        let part = quick_partition(20, &mut rng);
        let cfg = ScenarioConfig {
            scenario: Scenario::Colocated,
            n_clients: 20,
            days: 1,
            ..Default::default()
        };
        let b = build(&cfg, ModelKind::Vision, 10, &part);
        // daylight overlap between first two domains > 90%
        let sunny = |d: &PowerDomain| -> Vec<bool> {
            d.power_w.iter().map(|&p| p > 1.0).collect()
        };
        let a = sunny(&b.domains[0]);
        let c = sunny(&b.domains[1]);
        let agree =
            a.iter().zip(&c).filter(|(x, y)| x == y).count() as f64;
        assert!(agree / a.len() as f64 > 0.85);
    }

    #[test]
    fn unlimited_domain_flag_propagates() {
        let mut rng = Rng::new(3);
        let part = quick_partition(30, &mut rng);
        let cfg = ScenarioConfig {
            n_clients: 30,
            days: 1,
            unlimited_domain: Some(0),
            ..Default::default()
        };
        let b = build(&cfg, ModelKind::Vision, 10, &part);
        assert!(b.domains[0].unlimited);
        assert!(!b.domains[1].unlimited);
        // clients in domain 0 have zero load (unlimited capacity)
        for (i, c) in b.clients.iter().enumerate() {
            if c.domain == 0 {
                assert!(b.load_actual[i].iter().all(|&u| u == 0.0));
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(4);
        let part = quick_partition(10, &mut rng);
        let cfg = ScenarioConfig {
            n_clients: 10,
            days: 1,
            seed: 42,
            ..Default::default()
        };
        let a = build(&cfg, ModelKind::Seq, 10, &part);
        let b = build(&cfg, ModelKind::Seq, 10, &part);
        assert_eq!(a.domains[3].power_w, b.domains[3].power_w);
        assert_eq!(a.load_actual[5], b.load_actual[5]);
        assert_eq!(a.client_domains(), b.client_domains());
    }
}
