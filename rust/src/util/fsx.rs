//! Filesystem helpers with crash-safety and error context.
//!
//! Every report/checkpoint the repo writes (`CAMPAIGN_report.json`,
//! `BENCH_*.json`, snapshot checkpoints, campaign completion records)
//! goes through [`write_atomic`]: write to a same-directory temp file,
//! then rename over the target. On POSIX the rename is atomic, so a
//! crash mid-write can never leave a torn file that a resumed campaign
//! or the ci.sh ratchet then misreads — the target either holds the old
//! bytes or the complete new ones.

use std::path::Path;

use anyhow::{Context, Result};

/// Atomically replace `path` with `bytes` (temp file + rename). The
/// temp file lives next to the target (`.{name}.tmp`) so the rename
/// never crosses a filesystem boundary. Errors carry the path and the
/// operation that failed.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let name = path
        .file_name()
        .with_context(|| format!("atomic write: {} has no file name", path.display()))?;
    let tmp = match dir {
        Some(d) => d.join(format!(".{}.tmp", name.to_string_lossy())),
        None => std::path::PathBuf::from(format!(".{}.tmp", name.to_string_lossy())),
    };
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("writing temp file {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| {
        // don't leave the orphan temp file behind on a failed rename
        let _ = std::fs::remove_file(&tmp);
        format!("renaming {} over {}", tmp.display(), path.display())
    })?;
    Ok(())
}

/// `std::fs::read_to_string` with the path in the error message.
pub fn read_to_string(path: &Path) -> Result<String> {
    std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))
}

/// `std::fs::read` with the path in the error message.
pub fn read(path: &Path) -> Result<Vec<u8>> {
    std::fs::read(path).with_context(|| format!("reading {}", path.display()))
}

/// `std::fs::create_dir_all` with the path in the error message.
pub fn create_dir_all(path: &Path) -> Result<()> {
    std::fs::create_dir_all(path)
        .with_context(|| format!("creating directory {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fedzero_fsx_{}_{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_temp() {
        let dir = scratch("replace");
        let p = dir.join("out.json");
        write_atomic(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        write_atomic(&p, b"second, longer payload").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second, longer payload");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_errors_carry_the_path() {
        let missing = std::path::Path::new("/nonexistent/fedzero/spec.json");
        let err = read_to_string(missing).unwrap_err();
        assert!(
            format!("{err:#}").contains("/nonexistent/fedzero/spec.json"),
            "error should name the file: {err:#}"
        );
    }

    #[test]
    fn atomic_write_into_missing_dir_names_the_temp_path() {
        let p = std::path::Path::new("/nonexistent/fedzero/out.json");
        let err = write_atomic(p, b"x").unwrap_err();
        assert!(format!("{err:#}").contains("/nonexistent/fedzero"));
    }
}
