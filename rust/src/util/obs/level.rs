//! Leveled logging: the `obs::log!` macro's level gate.
//!
//! Three levels — `error` (stderr), `info` (stdout, the default:
//! byte-identical to the historical bare `println!` output), `debug`
//! (stdout, off by default). The effective level comes from, in
//! precedence order: [`set_level`] (the `--verbose`/`--quiet` CLI
//! flags), the `FEDZERO_LOG` environment variable (`error`/`info`/
//! `debug`, or `0`/`1`/`2`), then the `Info` default.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity; numerically ordered so `Error < Info < Debug`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Info = 1,
    Debug = 2,
}

const UNSET: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn parse_level(raw: &str) -> Option<Level> {
    match raw.trim().to_ascii_lowercase().as_str() {
        "error" | "quiet" | "0" => Some(Level::Error),
        "info" | "1" => Some(Level::Info),
        "debug" | "verbose" | "2" => Some(Level::Debug),
        _ => None,
    }
}

fn env_level() -> Level {
    std::env::var("FEDZERO_LOG")
        .ok()
        .as_deref()
        .and_then(parse_level)
        .unwrap_or(Level::Info)
}

/// The effective log level. First call resolves `FEDZERO_LOG` and
/// caches it; [`set_level`] overrides at any time.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        UNSET => {
            let l = env_level();
            // racing first readers resolve the same env value, so a
            // lost store is harmless
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
        1 => Level::Info,
        2 => Level::Debug,
        _ => Level::Error,
    }
}

/// Force the log level (CLI flags beat `FEDZERO_LOG`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether a message at level `l` should be emitted.
#[inline]
pub fn log_enabled(l: Level) -> bool {
    l <= level()
}

/// Leveled logging macro — use through the [`crate::util::obs`] alias:
/// `obs::log!(info, "...")`, `obs::log!(error, "...")`,
/// `obs::log!(debug, "...")`. `error` goes to stderr, `info`/`debug`
/// to stdout; at the default level the output is byte-identical to the
/// bare `println!`/`eprintln!` calls it replaced. A bare level
/// (`obs::log!(info)`) prints an empty line, like `println!()`.
#[macro_export]
macro_rules! obs_log {
    (error) => {{
        if $crate::util::obs::log_enabled($crate::util::obs::Level::Error) {
            eprintln!();
        }
    }};
    (error, $($arg:tt)*) => {{
        if $crate::util::obs::log_enabled($crate::util::obs::Level::Error) {
            eprintln!($($arg)*);
        }
    }};
    (info) => {{
        if $crate::util::obs::log_enabled($crate::util::obs::Level::Info) {
            println!();
        }
    }};
    (info, $($arg:tt)*) => {{
        if $crate::util::obs::log_enabled($crate::util::obs::Level::Info) {
            println!($($arg)*);
        }
    }};
    (debug) => {{
        if $crate::util::obs::log_enabled($crate::util::obs::Level::Debug) {
            println!();
        }
    }};
    (debug, $($arg:tt)*) => {{
        if $crate::util::obs::log_enabled($crate::util::obs::Level::Debug) {
            println!($($arg)*);
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_names_and_digits() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("INFO"), Some(Level::Info));
        assert_eq!(parse_level(" debug "), Some(Level::Debug));
        assert_eq!(parse_level("0"), Some(Level::Error));
        assert_eq!(parse_level("2"), Some(Level::Debug));
        assert_eq!(parse_level("nope"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn levels_order_and_gate() {
        assert!(Level::Error < Level::Info);
        assert!(Level::Info < Level::Debug);
        // set_level is process-global; restore the default afterwards
        let before = level();
        set_level(Level::Error);
        assert!(log_enabled(Level::Error));
        assert!(!log_enabled(Level::Info));
        set_level(Level::Debug);
        assert!(log_enabled(Level::Debug));
        set_level(before);
    }
}
