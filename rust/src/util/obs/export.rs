//! Machine-readable telemetry summary (`TELEMETRY.json`): one section
//! per instrumented subsystem — engine, solver, par, tree, journal,
//! chaos, campaign — each with its counters and histogram digests
//! (count, sum, mean, p50/p95/p99, sparkline). Every section is always
//! present (zeros included) so downstream schema checks are stable
//! regardless of which code paths a given run exercised.
//!
//! The summary is written as its OWN file, never merged into
//! deterministic reports: `CAMPAIGN_report.json`, `MetricsLog` saves
//! and journal bytes stay byte-identical with telemetry on or off
//! (latency digests are wall-clock and thus non-deterministic by
//! nature).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use super::{snapshot, Ctr, Hist, Snapshot};
use crate::util::fsx;
use crate::util::json::Json;

/// The subsystem sections, in report order.
pub const SUBSYSTEMS: [&str; 7] =
    ["engine", "solver", "par", "tree", "journal", "chaos", "campaign"];

fn hist_digest(s: &Snapshot, h: Hist) -> Json {
    let mut m = BTreeMap::new();
    m.insert("count".into(), Json::Num(s.hist_count(h) as f64));
    m.insert("sum".into(), Json::Num(s.hist_sum(h) as f64));
    m.insert("mean".into(), Json::Num(s.hist_mean(h)));
    m.insert("p50".into(), Json::Num(s.hist_percentile(h, 50.0)));
    m.insert("p95".into(), Json::Num(s.hist_percentile(h, 95.0)));
    m.insert("p99".into(), Json::Num(s.hist_percentile(h, 99.0)));
    m.insert("sparkline".into(), Json::Str(s.hist_sparkline(h)));
    Json::Obj(m)
}

/// Build the full summary document from a merged snapshot.
pub fn summary_json_from(s: &Snapshot) -> Json {
    let mut subs = BTreeMap::new();
    for sub in SUBSYSTEMS {
        let mut counters = BTreeMap::new();
        for c in Ctr::ALL {
            if c.subsystem() == sub {
                counters.insert(c.name().to_string(), Json::Num(s.ctr(c) as f64));
            }
        }
        let mut hists = BTreeMap::new();
        for h in Hist::ALL {
            if h.subsystem() == sub {
                hists.insert(h.name().to_string(), hist_digest(s, h));
            }
        }
        let mut sec = BTreeMap::new();
        sec.insert("counters".into(), Json::Obj(counters));
        sec.insert("histograms".into(), Json::Obj(hists));
        subs.insert(sub.to_string(), Json::Obj(sec));
    }
    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str("fedzero-telemetry-v1".into()));
    root.insert("subsystems".into(), Json::Obj(subs));
    Json::Obj(root)
}

/// Snapshot the current telemetry and build the summary document.
pub fn summary_json() -> Json {
    summary_json_from(&snapshot())
}

/// Write `TELEMETRY.json` to `path` (atomic temp + rename).
pub fn write_telemetry(path: &Path) -> Result<()> {
    fsx::write_atomic(path, summary_json().to_string_pretty().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::super::{add, observe, reset, set_enabled};
    use super::*;

    #[test]
    fn summary_always_lists_every_subsystem() {
        let _g = super::super::tests::TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        reset();
        add(Ctr::JournalFrames, 3);
        add(Ctr::JournalBytes, 300);
        observe(Hist::JournalAppendNs, 2048);
        let doc = summary_json();
        assert_eq!(
            doc.get("schema").unwrap().as_str().unwrap(),
            "fedzero-telemetry-v1"
        );
        let subs = doc.get("subsystems").unwrap();
        for sub in SUBSYSTEMS {
            let sec = subs.get(sub).unwrap_or_else(|| panic!("missing {sub}"));
            assert!(sec.get("counters").is_some());
            assert!(sec.get("histograms").is_some());
        }
        let j = subs.get("journal").unwrap();
        assert_eq!(
            j.get("counters").unwrap().get("frames").unwrap().as_f64().unwrap(),
            3.0
        );
        let ap = j.get("histograms").unwrap().get("append_ns").unwrap();
        assert_eq!(ap.get("count").unwrap().as_f64().unwrap(), 1.0);
        let p50 = ap.get("p50").unwrap().as_f64().unwrap();
        assert!((2048.0..4096.0).contains(&p50), "p50 {p50}");
        set_enabled(false);
        reset();
    }
}
