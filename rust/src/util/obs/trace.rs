//! Chrome trace-event exporter: spans recorded while tracing is armed
//! become `"ph": "X"` (complete) events that `chrome://tracing` and
//! Perfetto load directly. Timestamps are µs relative to the process
//! trace epoch; nesting falls out of enclosure — a `round` span's
//! interval contains its `select`/`grant`/`train`/`aggregate`/`eval`
//! children on the same thread track.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::fsx;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub(crate) struct TraceEvent {
    pub name: &'static str,
    pub tid: u32,
    pub ts_ns: u64,
    pub dur_ns: u64,
}

static EVENTS: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());

/// Record one completed span (called from `Span::drop` / `span_at`
/// when tracing is armed). Buffered thread-locally; flushed with the
/// owning thread's counter buffer.
pub(crate) fn record(name: &'static str, t0: Instant, dur: Duration) {
    let ts_ns = t0
        .checked_duration_since(super::epoch())
        .unwrap_or(Duration::ZERO)
        .as_nanos() as u64;
    super::push_event(TraceEvent {
        name,
        tid: super::local_tid(),
        ts_ns,
        dur_ns: dur.as_nanos() as u64,
    });
}

pub(crate) fn flush_events(mut evs: Vec<TraceEvent>) {
    EVENTS
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .append(&mut evs);
}

pub(crate) fn reset_events() {
    EVENTS.lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// All events collected so far, in canonical order: ascending start
/// time, longer (enclosing) spans first on ties, then thread and name.
fn drain_sorted() -> Vec<TraceEvent> {
    super::flush_thread();
    let mut evs = EVENTS
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .clone();
    evs.sort_by(|a, b| {
        a.ts_ns
            .cmp(&b.ts_ns)
            .then(b.dur_ns.cmp(&a.dur_ns))
            .then(a.tid.cmp(&b.tid))
            .then(a.name.cmp(b.name))
    });
    evs
}

/// Build the Chrome trace-event document (`{"traceEvents": [...]}`).
pub fn trace_json() -> Json {
    let events: Vec<Json> = drain_sorted()
        .into_iter()
        .map(|e| {
            let mut m = BTreeMap::new();
            m.insert("name".into(), Json::Str(e.name.to_string()));
            m.insert("cat".into(), Json::Str("fedzero".into()));
            m.insert("ph".into(), Json::Str("X".into()));
            m.insert("ts".into(), Json::Num(e.ts_ns as f64 / 1e3));
            m.insert("dur".into(), Json::Num(e.dur_ns as f64 / 1e3));
            m.insert("pid".into(), Json::Num(1.0));
            m.insert("tid".into(), Json::Num(e.tid as f64));
            Json::Obj(m)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(events));
    root.insert("displayTimeUnit".into(), Json::Str("ms".into()));
    Json::Obj(root)
}

/// Write the trace to `path` (atomic temp + rename).
pub fn write_trace(path: &Path) -> Result<()> {
    fsx::write_atomic(path, trace_json().to_string_pretty().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::super::{set_enabled, set_tracing, span, Hist};
    use super::*;

    #[test]
    fn traced_spans_become_nested_x_events() {
        let _g = super::super::tests::TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        set_tracing(true);
        super::super::reset();
        {
            let _round = span("round", Hist::RoundNs);
            let _select = span("select", Hist::SelectNs);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let doc = trace_json();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        // canonical order: the enclosing round sorts before its child
        assert_eq!(evs[0].get("name").unwrap().as_str().unwrap(), "round");
        assert_eq!(evs[1].get("name").unwrap().as_str().unwrap(), "select");
        for e in evs {
            assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
            assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("dur").unwrap().as_f64().unwrap() > 0.0);
        }
        // enclosure: round starts no later and ends no earlier
        let (rts, rdur) = (
            evs[0].get("ts").unwrap().as_f64().unwrap(),
            evs[0].get("dur").unwrap().as_f64().unwrap(),
        );
        let (sts, sdur) = (
            evs[1].get("ts").unwrap().as_f64().unwrap(),
            evs[1].get("dur").unwrap().as_f64().unwrap(),
        );
        assert!(rts <= sts && rts + rdur >= sts + sdur);
        set_enabled(false);
        super::super::reset();
    }
}
