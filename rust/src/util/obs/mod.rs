//! Zero-dependency structured telemetry: named counters, log₂-bucketed
//! latency/size histograms, nestable phase spans, and a leveled logging
//! macro — the crate-wide observability layer (ISSUE 10).
//!
//! # Determinism contract
//!
//! Enabling telemetry NEVER changes a single byte of deterministic
//! output (`MetricsLog`, model bits, journal bytes, campaign reports) —
//! the same discipline as `AggMode::Flat ≡ Tree` and the work-stealing
//! scheduler. Two properties make that hold by construction:
//!
//! 1. **Probes only write probe state.** A counter add or histogram
//!    observation touches a thread-local buffer; span guards read the
//!    clock but feed nothing back into simulation arithmetic.
//! 2. **The merge is canonical.** Thread-local buffers fold into one
//!    global accumulator as commutative u64 sums (counter totals,
//!    per-bucket histogram counts), so the merged telemetry itself is
//!    independent of thread scheduling and exit order — stronger than
//!    worker-index ordering: NO order can change a commutative sum.
//!    (Span *timestamps* are wall-clock and therefore non-deterministic
//!    by nature; they live only in the opt-in trace export.)
//!
//! # Zero overhead when disabled
//!
//! Every probe is a single relaxed-atomic load + branch on the global
//! enable flag. Disabled spans never call `Instant::now()` — the guard
//! holds `None` and its `Drop` is a no-op — so the instrumented binary
//! with telemetry off IS the perf baseline.
//!
//! # Probe taxonomy
//!
//! * [`Ctr`] — monotone counters, enum-indexed (array slot, no hashing):
//!   engine round/idle/ring activity, B&B nodes/incumbents/cuts, steal
//!   scheduler traffic, tree-aggregator arena behaviour, journal frames
//!   and bytes, chaos fault tallies, campaign cells and memo hits.
//! * [`Hist`] — 64-bucket log₂ histograms of ns latencies or byte
//!   sizes, rendered through [`stats::Histogram`] for sparklines and
//!   summarised as p50/p95/p99 via geometric interpolation inside the
//!   matching bucket.
//! * [`Span`] — nestable phase timers (`round` ⊃ `select`/`grant`/
//!   `train`/`aggregate`/`eval`): on drop they feed their histogram
//!   and, when tracing is armed, append a Chrome trace-event
//!   ([`trace`], `chrome://tracing` / Perfetto loads the file as-is).
//! * `obs::log!` — the leveled logging macro (error/info/debug) behind
//!   `FEDZERO_LOG` and the `--verbose`/`--quiet` CLI flags; see
//!   [`level`]. Default level (`info`) reproduces the historical
//!   `println!`/`eprintln!` output byte for byte.
//!
//! Exporters: [`trace::write_trace`] (`fedzero train --trace out.json`)
//! and [`export::write_telemetry`] (`TELEMETRY.json`, one section per
//! subsystem — engine, solver, par, tree, journal, chaos, campaign).

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::stats;

pub mod export;
pub mod level;
pub mod trace;

pub use export::{summary_json, write_telemetry};
pub use level::{log_enabled, set_level, Level};
pub use trace::write_trace;

// the leveled logging macro (defined in level.rs with #[macro_export],
// which exports it at the crate root as `obs_log!`); this alias lets
// call sites write `obs::log!(info, ...)`. A macro import lives in the
// macro namespace, so it coexists with the `level` module above.
pub use crate::obs_log as log;

// ---------------------------------------------------------------------------
// global enable flags
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static TRACING: AtomicBool = AtomicBool::new(false);

/// Whether counter/histogram collection is on. One relaxed load — this
/// is the branch every probe pays when telemetry is off.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Whether span trace-event collection is on (implies [`enabled`]).
#[inline(always)]
pub fn tracing() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Arm or disarm counter/histogram collection.
pub fn set_enabled(on: bool) {
    if on {
        epoch(); // pin the trace epoch before any span starts
    }
    ENABLED.store(on, Ordering::Relaxed);
    if !on {
        TRACING.store(false, Ordering::Relaxed);
    }
}

/// Arm or disarm span tracing (arming implies [`set_enabled`]`(true)`).
pub fn set_tracing(on: bool) {
    if on {
        set_enabled(true);
    }
    TRACING.store(on, Ordering::Relaxed);
}

/// The process-wide trace epoch: every span timestamp is reported
/// relative to this instant.
pub(crate) fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

// ---------------------------------------------------------------------------
// probe identifiers
// ---------------------------------------------------------------------------

/// Named monotone counters, enum-indexed into fixed arrays (no hashing
/// on the hot path). Grouped by the subsystem they instrument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Ctr {
    // sim/engine.rs
    EngineRounds,
    EngineIdleSteps,
    EngineRingAdvances,
    EngineRingRebuilds,
    EngineEvals,
    EngineSnapshots,
    // solver/mip.rs branch-and-bound
    BnbSolves,
    BnbNodes,
    BnbIncumbentUpdates,
    BnbBoundCuts,
    // util/par.rs work stealing
    StealFanouts,
    StealSteals,
    StealStolenItems,
    // fl/tree.rs hierarchical aggregation
    TreeAggregations,
    TreeShards,
    TreeArenaReuses,
    TreeArenaGrows,
    // coordinator/journal.rs
    JournalFrames,
    JournalBytes,
    // sim/chaos.rs fault plans (counted where the engine consumes them)
    ChaosDropouts,
    ChaosDelays,
    ChaosSlowdowns,
    ChaosCrashes,
    ChaosStaleRejected,
    // scenario/campaign.rs
    CampaignCells,
    CampaignMemoHits,
    CampaignMemoMisses,
}

impl Ctr {
    pub const COUNT: usize = 27;
    pub const ALL: [Ctr; Ctr::COUNT] = [
        Ctr::EngineRounds,
        Ctr::EngineIdleSteps,
        Ctr::EngineRingAdvances,
        Ctr::EngineRingRebuilds,
        Ctr::EngineEvals,
        Ctr::EngineSnapshots,
        Ctr::BnbSolves,
        Ctr::BnbNodes,
        Ctr::BnbIncumbentUpdates,
        Ctr::BnbBoundCuts,
        Ctr::StealFanouts,
        Ctr::StealSteals,
        Ctr::StealStolenItems,
        Ctr::TreeAggregations,
        Ctr::TreeShards,
        Ctr::TreeArenaReuses,
        Ctr::TreeArenaGrows,
        Ctr::JournalFrames,
        Ctr::JournalBytes,
        Ctr::ChaosDropouts,
        Ctr::ChaosDelays,
        Ctr::ChaosSlowdowns,
        Ctr::ChaosCrashes,
        Ctr::ChaosStaleRejected,
        Ctr::CampaignCells,
        Ctr::CampaignMemoHits,
        Ctr::CampaignMemoMisses,
    ];

    /// Subsystem section this counter is reported under.
    pub fn subsystem(self) -> &'static str {
        match self {
            Ctr::EngineRounds
            | Ctr::EngineIdleSteps
            | Ctr::EngineRingAdvances
            | Ctr::EngineRingRebuilds
            | Ctr::EngineEvals
            | Ctr::EngineSnapshots => "engine",
            Ctr::BnbSolves
            | Ctr::BnbNodes
            | Ctr::BnbIncumbentUpdates
            | Ctr::BnbBoundCuts => "solver",
            Ctr::StealFanouts | Ctr::StealSteals | Ctr::StealStolenItems => "par",
            Ctr::TreeAggregations
            | Ctr::TreeShards
            | Ctr::TreeArenaReuses
            | Ctr::TreeArenaGrows => "tree",
            Ctr::JournalFrames | Ctr::JournalBytes => "journal",
            Ctr::ChaosDropouts
            | Ctr::ChaosDelays
            | Ctr::ChaosSlowdowns
            | Ctr::ChaosCrashes
            | Ctr::ChaosStaleRejected => "chaos",
            Ctr::CampaignCells | Ctr::CampaignMemoHits | Ctr::CampaignMemoMisses => {
                "campaign"
            }
        }
    }

    /// Report key within the subsystem section.
    pub fn name(self) -> &'static str {
        match self {
            Ctr::EngineRounds => "rounds",
            Ctr::EngineIdleSteps => "idle_steps",
            Ctr::EngineRingAdvances => "ring_advances",
            Ctr::EngineRingRebuilds => "ring_rebuilds",
            Ctr::EngineEvals => "evals",
            Ctr::EngineSnapshots => "snapshots",
            Ctr::BnbSolves => "bnb_solves",
            Ctr::BnbNodes => "bnb_nodes",
            Ctr::BnbIncumbentUpdates => "bnb_incumbent_updates",
            Ctr::BnbBoundCuts => "bnb_bound_cuts",
            Ctr::StealFanouts => "fanouts",
            Ctr::StealSteals => "steals",
            Ctr::StealStolenItems => "stolen_items",
            Ctr::TreeAggregations => "aggregations",
            Ctr::TreeShards => "shards",
            Ctr::TreeArenaReuses => "arena_reuses",
            Ctr::TreeArenaGrows => "arena_grows",
            Ctr::JournalFrames => "frames",
            Ctr::JournalBytes => "bytes",
            Ctr::ChaosDropouts => "dropouts",
            Ctr::ChaosDelays => "delays",
            Ctr::ChaosSlowdowns => "slowdowns",
            Ctr::ChaosCrashes => "crashes",
            Ctr::ChaosStaleRejected => "stale_rejected",
            Ctr::CampaignCells => "cells",
            Ctr::CampaignMemoHits => "memo_hits",
            Ctr::CampaignMemoMisses => "memo_misses",
        }
    }
}

/// Log₂-bucketed histograms (64 buckets: bucket `i` covers values in
/// `[2^i, 2^(i+1))`, with 0 landing in bucket 0). Units are ns for
/// `*_ns` probes and bytes for `*_bytes` probes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Hist {
    SelectNs,
    GrantNs,
    TrainNs,
    AggregateNs,
    EvalNs,
    RoundNs,
    BnbSolveNs,
    ShardFillNs,
    JournalAppendNs,
    JournalFrameBytes,
    CellWallNs,
}

impl Hist {
    pub const COUNT: usize = 11;
    pub const ALL: [Hist; Hist::COUNT] = [
        Hist::SelectNs,
        Hist::GrantNs,
        Hist::TrainNs,
        Hist::AggregateNs,
        Hist::EvalNs,
        Hist::RoundNs,
        Hist::BnbSolveNs,
        Hist::ShardFillNs,
        Hist::JournalAppendNs,
        Hist::JournalFrameBytes,
        Hist::CellWallNs,
    ];

    pub fn subsystem(self) -> &'static str {
        match self {
            Hist::SelectNs
            | Hist::GrantNs
            | Hist::TrainNs
            | Hist::AggregateNs
            | Hist::EvalNs
            | Hist::RoundNs => "engine",
            Hist::BnbSolveNs => "solver",
            Hist::ShardFillNs => "tree",
            Hist::JournalAppendNs | Hist::JournalFrameBytes => "journal",
            Hist::CellWallNs => "campaign",
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Hist::SelectNs => "select_ns",
            Hist::GrantNs => "grant_ns",
            Hist::TrainNs => "train_ns",
            Hist::AggregateNs => "aggregate_ns",
            Hist::EvalNs => "eval_ns",
            Hist::RoundNs => "round_ns",
            Hist::BnbSolveNs => "bnb_solve_ns",
            Hist::ShardFillNs => "shard_fill_ns",
            Hist::JournalAppendNs => "append_ns",
            Hist::JournalFrameBytes => "frame_bytes",
            Hist::CellWallNs => "cell_wall_ns",
        }
    }
}

const BUCKETS: usize = 64;

#[inline]
fn bucket_of(v: u64) -> usize {
    // floor(log2(max(v, 1))): 0 and 1 land in bucket 0
    63 - (v | 1).leading_zeros() as usize
}

// ---------------------------------------------------------------------------
// thread-local collection + canonical global merge
// ---------------------------------------------------------------------------

struct Acc {
    ctrs: [u64; Ctr::COUNT],
    buckets: [[u64; BUCKETS]; Hist::COUNT],
    sums: [u64; Hist::COUNT],
}

impl Acc {
    const ZERO: Acc = Acc {
        ctrs: [0; Ctr::COUNT],
        buckets: [[0; BUCKETS]; Hist::COUNT],
        sums: [0; Hist::COUNT],
    };

    fn merge_from(&mut self, other: &Acc) {
        for (a, b) in self.ctrs.iter_mut().zip(&other.ctrs) {
            *a += b;
        }
        for (ah, bh) in self.buckets.iter_mut().zip(&other.buckets) {
            for (a, b) in ah.iter_mut().zip(bh) {
                *a += b;
            }
        }
        for (a, b) in self.sums.iter_mut().zip(&other.sums) {
            *a += b;
        }
    }
}

static GLOBAL: Mutex<Acc> = Mutex::new(Acc::ZERO);
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

struct LocalBuf {
    acc: Acc,
    events: Vec<trace::TraceEvent>,
    tid: u32,
    dirty: bool,
}

impl LocalBuf {
    fn new() -> LocalBuf {
        LocalBuf {
            acc: Acc::ZERO,
            events: Vec::new(),
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            dirty: false,
        }
    }

    fn flush(&mut self) {
        if self.dirty {
            // counter and bucket merges are commutative u64 sums, so the
            // fold is canonical no matter which thread flushes first
            let mut g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
            g.merge_from(&self.acc);
            self.acc = Acc::ZERO;
            self.dirty = false;
        }
        if !self.events.is_empty() {
            trace::flush_events(std::mem::take(&mut self.events));
        }
    }
}

impl Drop for LocalBuf {
    // worker threads (std::thread::scope fan-outs) die at the join;
    // their buffers flush here so no probe is ever lost
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf::new());
}

/// Add `n` to counter `c`. One relaxed load + branch when disabled.
#[inline]
pub fn add(c: Ctr, n: u64) {
    if !enabled() {
        return;
    }
    let _ = LOCAL.try_with(|l| {
        let mut l = l.borrow_mut();
        l.acc.ctrs[c as usize] += n;
        l.dirty = true;
    });
}

/// Record value `v` (ns or bytes) into histogram `h`.
#[inline]
pub fn observe(h: Hist, v: u64) {
    if !enabled() {
        return;
    }
    let _ = LOCAL.try_with(|l| {
        let mut l = l.borrow_mut();
        l.acc.buckets[h as usize][bucket_of(v)] += 1;
        l.acc.sums[h as usize] += v;
        l.dirty = true;
    });
}

pub(crate) fn push_event(ev: trace::TraceEvent) {
    let _ = LOCAL.try_with(|l| l.borrow_mut().events.push(ev));
}

pub(crate) fn local_tid() -> u32 {
    LOCAL.try_with(|l| l.borrow().tid).unwrap_or(u32::MAX)
}

/// Flush the calling thread's buffers into the global accumulator.
/// Exporters call this on the main thread; worker threads flush
/// automatically on exit.
pub fn flush_thread() {
    let _ = LOCAL.try_with(|l| l.borrow_mut().flush());
}

/// Zero all collected telemetry (flushes the calling thread first).
/// Buffers on other *live* threads are not reclaimed — callers that
/// reset between measurement windows (tests, benches) drive all work
/// from one thread and join fan-outs in between, so nothing is in
/// flight.
pub fn reset() {
    flush_thread();
    *GLOBAL.lock().unwrap_or_else(|e| e.into_inner()) = Acc::ZERO;
    trace::reset_events();
}

// ---------------------------------------------------------------------------
// spans and timers
// ---------------------------------------------------------------------------

struct SpanActive {
    name: &'static str,
    hist: Hist,
    t0: Instant,
    traced: bool,
}

/// RAII phase timer: on drop, records its elapsed ns into `hist` and —
/// when created by [`span`] with tracing armed — appends a Chrome
/// trace event. Holds `None` when telemetry is off: creation is one
/// relaxed load and the drop is a no-op (the clock is never read).
pub struct Span(Option<SpanActive>);

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(a) = self.0.take() {
            let dur = a.t0.elapsed();
            observe(a.hist, dur.as_nanos() as u64);
            if a.traced && tracing() {
                trace::record(a.name, a.t0, dur);
            }
        }
    }
}

/// Start a traced phase span feeding `hist`.
#[inline]
pub fn span(name: &'static str, hist: Hist) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(SpanActive { name, hist, t0: Instant::now(), traced: true }))
}

/// Start a histogram-only timer (no trace event even when tracing is
/// armed — for high-frequency probes like per-frame journal appends).
#[inline]
pub fn timer(hist: Hist) -> Span {
    if !enabled() {
        return Span(None);
    }
    Span(Some(SpanActive { name: "", hist, t0: Instant::now(), traced: false }))
}

/// Record an already-measured phase (callers that had to read the clock
/// anyway, e.g. the engine's `select_time` metric): feeds `hist` and,
/// when tracing, a trace event anchored at `t0`.
#[inline]
pub fn span_at(name: &'static str, t0: Instant, dur: std::time::Duration, hist: Hist) {
    if !enabled() {
        return;
    }
    observe(hist, dur.as_nanos() as u64);
    if tracing() {
        trace::record(name, t0, dur);
    }
}

// ---------------------------------------------------------------------------
// snapshot (read side)
// ---------------------------------------------------------------------------

/// A merged copy of all telemetry collected so far (calling thread
/// flushed first).
#[derive(Clone)]
pub struct Snapshot {
    ctrs: [u64; Ctr::COUNT],
    buckets: [[u64; BUCKETS]; Hist::COUNT],
    sums: [u64; Hist::COUNT],
}

impl Snapshot {
    pub fn ctr(&self, c: Ctr) -> u64 {
        self.ctrs[c as usize]
    }

    pub fn hist_count(&self, h: Hist) -> u64 {
        self.buckets[h as usize].iter().sum()
    }

    pub fn hist_sum(&self, h: Hist) -> u64 {
        self.sums[h as usize]
    }

    pub fn hist_mean(&self, h: Hist) -> f64 {
        let n = self.hist_count(h);
        if n == 0 {
            return 0.0;
        }
        self.hist_sum(h) as f64 / n as f64
    }

    /// Percentile (q in [0, 100]) with geometric interpolation inside
    /// the matching log₂ bucket — exact to within one bucket's span.
    pub fn hist_percentile(&self, h: Hist, q: f64) -> f64 {
        let b = &self.buckets[h as usize];
        let total: u64 = b.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 100.0) / 100.0) * total as f64;
        let mut cum = 0u64;
        for (i, &c) in b.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if next as f64 >= target {
                let frac = if c == 0 {
                    0.0
                } else {
                    ((target - cum as f64) / c as f64).clamp(0.0, 1.0)
                };
                return (i as f64 + frac).exp2();
            }
            cum = next;
        }
        ((BUCKETS - 1) as f64).exp2()
    }

    /// Render the occupied bucket range through [`stats::Histogram`].
    pub fn hist_sparkline(&self, h: Hist) -> String {
        let b = &self.buckets[h as usize];
        let lo = b.iter().position(|&c| c > 0);
        let Some(lo) = lo else {
            return String::new();
        };
        let hi = b.iter().rposition(|&c| c > 0).unwrap_or(lo);
        let mut sh = stats::Histogram::new(lo as f64, (hi + 1) as f64, hi - lo + 1);
        sh.counts.copy_from_slice(&b[lo..=hi]);
        sh.sparkline()
    }
}

/// Take a merged snapshot of everything collected so far.
pub fn snapshot() -> Snapshot {
    flush_thread();
    let g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    Snapshot { ctrs: g.ctrs, buckets: g.buckets, sums: g.sums }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::par;

    // obs state is process-global; tests serialise on this lock so
    // parallel `cargo test` threads don't interleave enable/reset
    pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_probes_are_noops() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        reset();
        add(Ctr::EngineRounds, 5);
        observe(Hist::SelectNs, 123);
        let sp = span("x", Hist::RoundNs);
        assert!(sp.0.is_none(), "disabled span must not read the clock");
        drop(sp);
        let s = snapshot();
        assert_eq!(s.ctr(Ctr::EngineRounds), 0);
        assert_eq!(s.hist_count(Hist::SelectNs), 0);
    }

    #[test]
    fn counters_merge_exactly_across_stealing_workers() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        reset();
        let n = 10_000usize;
        for &workers in &[1usize, 2, 8] {
            par::steal::steal_exec(n, workers, |_| (), |i, _| {
                add(Ctr::BnbNodes, 1);
                observe(Hist::ShardFillNs, i as u64);
            });
        }
        let s = snapshot();
        assert_eq!(s.ctr(Ctr::BnbNodes), 3 * n as u64);
        assert_eq!(s.hist_count(Hist::ShardFillNs), 3 * n as u64);
        // sums are exact, not bucketed: 3 * Σ 0..n
        assert_eq!(s.hist_sum(Hist::ShardFillNs), 3 * (n as u64 * (n as u64 - 1) / 2));
        set_enabled(false);
        reset();
    }

    #[test]
    fn spans_feed_their_histogram() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        reset();
        for _ in 0..4 {
            let _s = span("phase", Hist::EvalNs);
        }
        let s = snapshot();
        assert_eq!(s.hist_count(Hist::EvalNs), 4);
        assert!(s.hist_percentile(Hist::EvalNs, 50.0) >= 1.0);
        set_enabled(false);
        reset();
    }

    #[test]
    fn log2_percentiles_track_known_distributions() {
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        reset();
        // 1000 observations of exactly 1024 ns: every percentile lands
        // inside bucket 10, i.e. in [1024, 2048)
        for _ in 0..1000 {
            observe(Hist::JournalAppendNs, 1024);
        }
        let s = snapshot();
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            let p = s.hist_percentile(Hist::JournalAppendNs, q);
            assert!((1024.0..2048.0).contains(&p), "q={q}: {p}");
        }
        assert_eq!(s.hist_mean(Hist::JournalAppendNs), 1024.0);
        assert!(!s.hist_sparkline(Hist::JournalAppendNs).is_empty());
        set_enabled(false);
        reset();
    }

    #[test]
    fn bucket_of_is_floor_log2() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), 63);
    }

    #[test]
    fn enum_tables_are_consistent() {
        for (i, c) in Ctr::ALL.iter().enumerate() {
            assert_eq!(*c as usize, i, "Ctr::ALL order drifted at {i}");
        }
        for (i, h) in Hist::ALL.iter().enumerate() {
            assert_eq!(*h as usize, i, "Hist::ALL order drifted at {i}");
        }
        // every subsystem the acceptance criteria name is represented
        for sub in ["engine", "solver", "par", "tree", "journal", "chaos", "campaign"] {
            assert!(
                Ctr::ALL.iter().any(|c| c.subsystem() == sub)
                    || Hist::ALL.iter().any(|h| h.subsystem() == sub),
                "no probe for subsystem {sub}"
            );
        }
    }
}
