//! Micro-benchmark harness (offline substitute for criterion).
//!
//! Auto-calibrates iteration counts to a target measurement window, runs
//! warmup + multiple samples, and reports mean / median / p95 with a
//! machine-readable one-line summary (the bench binaries under
//! `rust/benches/` are `harness = false` and drive this directly).

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters_per_sample: u64,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }

    pub fn median_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn p95_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 95.0)
    }

    pub fn p50_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn p99_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 99.0)
    }

    /// Sparkline of the sample distribution over [min, max].
    pub fn sparkline(&self) -> String {
        let (lo, hi) = (stats::min(&self.samples_ns), stats::max(&self.samples_ns));
        if self.samples_ns.is_empty() || !(hi > lo) {
            // degenerate spread: a flat one-bin line
            return "█".into();
        }
        let bins = self.samples_ns.len().clamp(2, 24);
        // widen the top edge slightly so the max sample lands in-range
        let mut h = stats::Histogram::new(lo, hi + (hi - lo) * 1e-9, bins);
        for &s in &self.samples_ns {
            h.push(s);
        }
        h.sparkline()
    }

    pub fn report(&self) -> String {
        format!(
            "bench {:<40} mean {:>12}  p50 {:>12}  p95 {:>12}  p99 {:>12}  {}  ({} samples x {} iters)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p50_ns()),
            fmt_ns(self.p95_ns()),
            fmt_ns(self.p99_ns()),
            self.sparkline(),
            self.samples_ns.len(),
            self.iters_per_sample,
        )
    }

    /// Standard JSON digest for `BENCH_*.json` files.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("mean_ns".into(), Json::Num(self.mean_ns()));
        m.insert("median_ns".into(), Json::Num(self.median_ns()));
        m.insert("p50_ns".into(), Json::Num(self.p50_ns()));
        m.insert("p95_ns".into(), Json::Num(self.p95_ns()));
        m.insert("p99_ns".into(), Json::Num(self.p99_ns()));
        m.insert("sparkline".into(), Json::Str(self.sparkline()));
        m.insert(
            "samples".into(),
            Json::Num(self.samples_ns.len() as f64),
        );
        m.insert(
            "iters_per_sample".into(),
            Json::Num(self.iters_per_sample as f64),
        );
        Json::Obj(m)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark configuration; defaults match a ~1 s budget per benchmark.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub warmup: Duration,
    pub sample_time: Duration,
    pub samples: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            warmup: Duration::from_millis(150),
            sample_time: Duration::from_millis(60),
            samples: 12,
        }
    }
}

/// Fast config for expensive end-to-end benches.
pub fn quick() -> Config {
    Config {
        warmup: Duration::from_millis(20),
        sample_time: Duration::from_millis(120),
        samples: 4,
    }
}

/// Run `f` under the harness and print + return the result. The closure's
/// output is passed through `black_box` so the optimiser cannot elide it.
pub fn bench<T, F: FnMut() -> T>(name: &str, cfg: Config, mut f: F) -> BenchResult {
    // Warmup + calibration: find iters such that one sample ~ sample_time.
    let warm_start = Instant::now();
    let mut iters_done = 0u64;
    while warm_start.elapsed() < cfg.warmup || iters_done == 0 {
        black_box(f());
        iters_done += 1;
        if iters_done > 1_000_000 {
            break;
        }
    }
    let per_iter =
        warm_start.elapsed().as_nanos() as f64 / iters_done as f64;
    let iters = ((cfg.sample_time.as_nanos() as f64 / per_iter).ceil() as u64)
        .clamp(1, 10_000_000);

    let mut samples_ns = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(f());
        }
        samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    let result = BenchResult {
        name: name.to_string(),
        iters_per_sample: iters,
        samples_ns,
    };
    crate::util::obs::log!(info, "{}", result.report());
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_spin() {
        let r = bench(
            "spin_1k",
            Config {
                warmup: Duration::from_millis(5),
                sample_time: Duration::from_millis(5),
                samples: 4,
            },
            || {
                let mut acc = 0u64;
                for i in 0..1000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            },
        );
        assert!(r.mean_ns() > 0.0);
        assert!(r.samples_ns.len() == 4);
        assert!(r.iters_per_sample >= 1);
        assert!(r.p50_ns() <= r.p95_ns() && r.p95_ns() <= r.p99_ns());
        assert!(!r.sparkline().is_empty());
        let j = r.to_json();
        for key in ["mean_ns", "p50_ns", "p95_ns", "p99_ns", "sparkline"] {
            assert!(j.get(key).is_some(), "to_json missing {key}");
        }
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2_000_000_000.0).contains(" s"));
    }
}
