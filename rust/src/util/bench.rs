//! Micro-benchmark harness (offline substitute for criterion).
//!
//! Auto-calibrates iteration counts to a target measurement window, runs
//! warmup + multiple samples, and reports mean / median / p95 with a
//! machine-readable one-line summary (the bench binaries under
//! `rust/benches/` are `harness = false` and drive this directly).

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters_per_sample: u64,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }

    pub fn median_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn p95_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 95.0)
    }

    pub fn report(&self) -> String {
        format!(
            "bench {:<40} mean {:>12}  median {:>12}  p95 {:>12}  ({} samples x {} iters)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.median_ns()),
            fmt_ns(self.p95_ns()),
            self.samples_ns.len(),
            self.iters_per_sample,
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark configuration; defaults match a ~1 s budget per benchmark.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub warmup: Duration,
    pub sample_time: Duration,
    pub samples: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            warmup: Duration::from_millis(150),
            sample_time: Duration::from_millis(60),
            samples: 12,
        }
    }
}

/// Fast config for expensive end-to-end benches.
pub fn quick() -> Config {
    Config {
        warmup: Duration::from_millis(20),
        sample_time: Duration::from_millis(120),
        samples: 4,
    }
}

/// Run `f` under the harness and print + return the result. The closure's
/// output is passed through `black_box` so the optimiser cannot elide it.
pub fn bench<T, F: FnMut() -> T>(name: &str, cfg: Config, mut f: F) -> BenchResult {
    // Warmup + calibration: find iters such that one sample ~ sample_time.
    let warm_start = Instant::now();
    let mut iters_done = 0u64;
    while warm_start.elapsed() < cfg.warmup || iters_done == 0 {
        black_box(f());
        iters_done += 1;
        if iters_done > 1_000_000 {
            break;
        }
    }
    let per_iter =
        warm_start.elapsed().as_nanos() as f64 / iters_done as f64;
    let iters = ((cfg.sample_time.as_nanos() as f64 / per_iter).ceil() as u64)
        .clamp(1, 10_000_000);

    let mut samples_ns = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(f());
        }
        samples_ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    let result = BenchResult {
        name: name.to_string(),
        iters_per_sample: iters,
        samples_ns,
    };
    println!("{}", result.report());
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_spin() {
        let r = bench(
            "spin_1k",
            Config {
                warmup: Duration::from_millis(5),
                sample_time: Duration::from_millis(5),
                samples: 4,
            },
            || {
                let mut acc = 0u64;
                for i in 0..1000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            },
        );
        assert!(r.mean_ns() > 0.0);
        assert!(r.samples_ns.len() == 4);
        assert!(r.iters_per_sample >= 1);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2_000_000_000.0).contains(" s"));
    }
}
