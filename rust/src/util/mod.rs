//! Self-contained utility substrates.
//!
//! The build environment is fully offline with only the `xla` crate (and
//! `anyhow`) vendored, so everything a framework normally pulls from
//! crates.io — PRNG + distributions, JSON, descriptive statistics, CLI
//! parsing, a micro-benchmark harness and a property-testing harness — is
//! implemented here from scratch and unit-tested.

pub mod bench;
pub mod cli;
pub mod fsx;
pub mod json;
pub mod obs;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
