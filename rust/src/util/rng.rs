//! Deterministic PRNG + sampling distributions.
//!
//! Core generator is xoshiro256** (Blackman/Vigna) seeded via SplitMix64 —
//! fast, high-quality, and trivially reproducible across runs, which the
//! experiment harness depends on (every simulation takes an explicit seed).
//!
//! Distributions implemented on top: uniform, Bernoulli, normal
//! (Box–Muller), exponential, log-normal, gamma (Marsaglia–Tsang),
//! Dirichlet, categorical/weighted choice, permutation shuffle.

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of Box–Muller.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-client / per-domain
    /// processes that must not perturb each other's sequences).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The full generator state — the four xoshiro words plus the cached
    /// Box–Muller spare — for checkpointing. [`Rng::from_state`] with
    /// these values resumes the exact output sequence.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator mid-sequence from a [`Rng::state`] capture.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Rng { s, gauss_spare }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) — Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / rate
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; shape < 1 handled by boosting.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = self.f64().max(f64::MIN_POSITIVE);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3;
            }
        }
    }

    /// Dirichlet(alpha, ..., alpha) over k categories.
    pub fn dirichlet_sym(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in v.iter_mut() {
            *x /= s;
        }
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Index sampled proportionally to non-negative weights.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(4);
        for shape in [0.5, 1.0, 2.5, 10.0] {
            let n = 20_000;
            let m: f64 =
                (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((m - shape).abs() < 0.15 * shape.max(1.0), "{shape} {m}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let v = r.dirichlet_sym(0.5, 10);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed() {
        // alpha=0.1 should concentrate mass: max component usually > 0.5
        let mut r = Rng::new(6);
        let mut hits = 0;
        for _ in 0..200 {
            let v = r.dirichlet_sym(0.1, 10);
            if v.iter().cloned().fold(0.0, f64::max) > 0.5 {
                hits += 1;
            }
        }
        assert!(hits > 100, "hits={hits}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(7);
        for _ in 0..50 {
            let mut v = r.sample_indices(20, 10);
            v.sort_unstable();
            v.dedup();
            assert_eq!(v.len(), 10);
            assert!(v.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_choice(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_capture_resumes_exact_sequence() {
        let mut r = Rng::new(11);
        for _ in 0..17 {
            r.next_u64();
        }
        r.normal(); // leaves a gauss spare cached
        let (s, spare) = r.state();
        assert!(spare.is_some(), "normal() should cache its second output");
        let mut resumed = Rng::from_state(s, spare);
        for _ in 0..50 {
            assert_eq!(r.normal().to_bits(), resumed.normal().to_bits());
            assert_eq!(r.next_u64(), resumed.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let av: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
