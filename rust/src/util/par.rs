//! Deterministic parallelism on `std::thread::scope`: static fork-join
//! splits plus a work-stealing scheduler, both bit-identical to serial.
//!
//! The offline vendor set has no `rayon`, so the selection pipeline's
//! data-parallel stages (arena construction, standalone scoring, swap
//! candidate scanning, per-domain round execution) use this instead.
//! Every entry point takes a `min_serial` threshold below which it runs
//! inline — the unit-test and evaluation-scale instances never pay
//! thread-spawn overhead.
//!
//! Two families live here:
//!
//! * **Static splits** — `chunking` (how many units per worker) and
//!   `spawn_blocks` (the split-and-spawn walk every in-place fill
//!   funnels through); [`par_ranges`] owns the collect-style maps.
//!   Everything else ([`par_map`], [`par_fill_rows`],
//!   [`try_par_fill_rows`], [`par_fill_slice`], ...) is a thin wrapper,
//!   so a change to the worker/chunk computation cannot silently diverge
//!   between callers. Right for near-uniform per-item cost.
//! * **Work stealing** — the [`steal`] submodule. Same split arithmetic
//!   to *seed* per-worker deques, but an idle worker steals chunks from
//!   a busy one instead of waiting at the join, so wall-clock tracks
//!   total work instead of the slowest uniform slice. Right for skewed
//!   per-item cost (deep B&B subtrees, one giant energy domain, a
//!   monster campaign cell).
//!
//! # Why determinism survives stealing
//!
//! The schedule (who runs item `i`, and when) is timing-dependent under
//! stealing — but no output ever depends on the schedule:
//!
//! 1. **Results are index-addressed.** Every item writes only slots
//!    owned by its index (a row, a `TrainJob`, a campaign cell slot),
//!    and the scheduler hands each index to exactly one worker (a
//!    single CAS claims it — see [`steal`]). The bytes written for item
//!    `i` are the same serial expression of `i` regardless of which
//!    worker runs it.
//! 2. **Reductions are canonical.** Anything folded *across* items
//!    (FedAvg partials, B&B incumbents, smallest-failing-index errors)
//!    is reduced in a fixed order — index order, ascending domain id,
//!    or `(objective, lex-smallest)` — after the join, never in
//!    completion order. f32/f64 addition is non-associative, so this is
//!    what makes the guarantee *bitwise*, not just approximate.
//!
//! Together: output at any worker count, including 1, is bit-identical.
//! Thread count itself is overridable via `FEDZERO_THREADS` (see
//! [`threads`]) — a performance knob only, never a correctness one.

use std::sync::OnceLock;
use std::thread;

/// The ONE table of fan-out thresholds for every parallel stage in the
/// crate (satellite: these used to be duplicated per module —
/// `PAR_MIN_ROWS` in `selection::{ring,arena}`, `PAR_MIN_*` in
/// `solver::mip` — and could drift apart silently). Below a threshold
/// the stage runs inline; results are bit-identical either way, so these
/// are pure performance knobs: thread spawn/join costs a few µs, which
/// only pays off once a stage has enough independent work. The worker
/// count itself is the remaining knob: `FEDZERO_THREADS=<n>` overrides
/// [`threads`](super::threads) without code edits.
pub mod thresholds {
    /// Rows below which in-place row fills stay single-threaded (ring
    /// rebuild/advance/catch-up, arena reachability fills). One row is a
    /// handful of float writes, so fan-out needs thousands of them.
    pub const MIN_FILL_ROWS: usize = 2048;
    /// Candidate counts below which per-client map stages stay serial
    /// (standalone scoring, swap-candidate scans).
    pub const MIN_CLIENTS: usize = 4096;
    /// Domain-group counts below which per-domain evaluation stays
    /// serial (groups are tiny flow solves; see `MIN_EVAL_WORK`).
    pub const MIN_DOMAIN_GROUPS: usize = 16;
    /// `chosen·steps` product below which `evaluate_view` stays serial —
    /// branch-and-bound calls it on every node, where spawn/join would
    /// dwarf a handful of tiny flow solves.
    pub const MIN_EVAL_WORK: usize = 8192;
    /// Candidate count at which the exact solver fans independent root
    /// subtrees out across workers (small instances finish faster than
    /// the frontier split costs).
    pub const BNB_MIN_CLIENTS: usize = 64;
    /// Dirty-client count at which `IncrSelState::advance` fans its
    /// reach re-derivation walks out across workers (each walk is an
    /// O(√d_max) read-only fold; the counter/append phase and the
    /// reach/counter application stay serial either way).
    pub const REDERIVE_CLIENTS: usize = 4096;
    /// Engine round execution: minimum domains spanned by a round before
    /// the per-domain grant computation fans out…
    pub const ROUND_DOMAINS: usize = 8;
    /// …AND minimum selected clients (both gates must pass; water-filling
    /// a few slots is cheaper than a spawn).
    pub const ROUND_SLOTS: usize = 256;
    /// Hierarchical aggregation: minimum domain groups in a round before
    /// the per-domain partial fills fan out…
    pub const TREE_GROUPS: usize = 8;
    /// …AND minimum total work (participants × parameters; both gates
    /// must pass — a few small partial rows fill faster inline).
    pub const TREE_WORK: usize = 1 << 15;
}

/// Number of worker threads to fan out to (>= 1).
///
/// Defaults to [`std::thread::available_parallelism`]. The
/// `FEDZERO_THREADS` environment variable overrides it (any integer
/// >= 1; unset, empty, `0` or unparsable values fall back to the
/// default) so bench runs can pin worker counts without code edits —
/// like every knob in [`thresholds`], this is a pure performance
/// setting: output is bit-identical at any worker count.
pub fn threads() -> usize {
    static OVERRIDE: OnceLock<Option<usize>> = OnceLock::new();
    let forced = OVERRIDE
        .get_or_init(|| std::env::var("FEDZERO_THREADS").ok().as_deref().and_then(parse_threads_override));
    if let Some(n) = *forced {
        return n;
    }
    thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
}

/// `FEDZERO_THREADS` value parsing (split out of [`threads`] so it can
/// be unit-tested — the env read itself is cached process-wide).
fn parse_threads_override(raw: &str) -> Option<usize> {
    raw.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}

/// The shared chunking policy: ceil-split `n` items over the available
/// workers so every chunk is non-empty (many-core hosts, small n).
/// Returns (chunk_size, n_chunks).
fn chunking(n: usize) -> (usize, usize) {
    let workers = threads().min(n).max(1);
    let chunk = (n + workers - 1) / workers;
    let n_chunks = (n + chunk - 1) / chunk;
    (chunk, n_chunks)
}

/// The shared block-spawn walk for every in-place parallel fill: split
/// `out` — interpreted as `out.len() / unit` units of `unit` elements —
/// into contiguous blocks of `units_per_block` units, run
/// `f(first_unit_index, block)` on each block in its own scoped thread,
/// and return the per-block results in block order. All mutable-fill
/// entry points funnel through here so the split arithmetic cannot
/// silently diverge between them (the same promise [`chunking`] makes
/// for chunk sizing).
fn spawn_blocks<T, R, F>(
    out: &mut [T],
    unit: usize,
    units_per_block: usize,
    f: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut [T]) -> R + Sync,
{
    let n_units = out.len() / unit;
    thread::scope(|s| {
        let f = &f;
        let mut handles = Vec::new();
        let mut rest: &mut [T] = out;
        let mut u0 = 0usize;
        while u0 < n_units {
            let take = units_per_block.min(n_units - u0);
            let tmp = std::mem::take(&mut rest);
            let (head, tail) = tmp.split_at_mut(take * unit);
            rest = tail;
            let start = u0;
            handles.push(s.spawn(move || f(start, head)));
            u0 += take;
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("spawn_blocks worker panicked"))
            .collect()
    })
}

/// Split `0..n` into contiguous ranges, run `f(start, end)` on each (in
/// parallel when `n >= min_serial`), and return the per-range results in
/// range order. Lets callers keep per-thread scratch state inside `f`.
/// This is the core primitive every map-style wrapper builds on.
pub fn par_ranges<T, F>(n: usize, min_serial: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    if n < min_serial || threads() <= 1 {
        return vec![f(0, n)];
    }
    let (chunk, n_chunks) = chunking(n);
    thread::scope(|s| {
        let handles: Vec<_> = (0..n_chunks)
            .map(|k| {
                let f = &f;
                s.spawn(move || {
                    let start = k * chunk;
                    let end = ((k + 1) * chunk).min(n);
                    f(start, end)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_ranges worker panicked"))
            .collect()
    })
}

/// [`par_map`] with per-worker scratch state: `init()` builds one scratch
/// per worker (or one total on the serial path), and `f(i, scratch)` may
/// mutate it freely between calls. `f` must be index-deterministic given
/// *any* scratch state (scratch is reuse-only — buffers, workspaces), so
/// the output is identical to the serial map.
pub fn par_map_scratch<T, S, I, F>(n: usize, min_serial: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> T + Sync,
{
    let parts = par_ranges(n, min_serial, |start, end| {
        let mut scratch = init();
        (start..end).map(|i| f(i, &mut scratch)).collect::<Vec<T>>()
    });
    let mut out = Vec::with_capacity(n);
    for part in parts {
        out.extend(part);
    }
    out
}

/// `(0..n).map(f)` collected in order, chunked across threads when
/// `n >= min_serial` and more than one core is available. `f` must be
/// index-deterministic: the output is identical to the serial map.
pub fn par_map<T, F>(n: usize, min_serial: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_scratch(n, min_serial, || (), |i, _| f(i))
}

/// [`par_fill_rows`] with per-worker scratch state (same contract as
/// [`par_map_scratch`]): fill `out` (length = rows × `row_len`) row by
/// row via `f(row_index, row_slice, scratch)`, fanning contiguous row
/// blocks out across threads when there are at least `min_serial_rows`
/// rows. Rows are disjoint, so parallel and serial fills write identical
/// bytes. Used by the simulation engine to recompute per-domain grant
/// rows in place — the row buffers keep their capacity across steps and
/// the request/active scratch is reused within each worker.
pub fn par_fill_rows_scratch<T, S, I, F>(
    out: &mut [T],
    row_len: usize,
    min_serial_rows: usize,
    init: I,
    f: F,
) where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut [T], &mut S) + Sync,
{
    if row_len == 0 || out.is_empty() {
        return;
    }
    debug_assert_eq!(out.len() % row_len, 0, "out is not a whole number of rows");
    let n_rows = out.len() / row_len;
    if n_rows < min_serial_rows || threads() <= 1 {
        let mut scratch = init();
        for (r, row) in out.chunks_mut(row_len).enumerate() {
            f(r, row, &mut scratch);
        }
        return;
    }
    let (rows_per, _) = chunking(n_rows);
    spawn_blocks(out, row_len, rows_per, |start, head| {
        let mut scratch = init();
        for (k, row) in head.chunks_mut(row_len).enumerate() {
            f(start + k, row, &mut scratch);
        }
    });
}

/// Fill `out` (length = rows × `row_len`) row by row via
/// `f(row_index, row_slice)`, fanning contiguous row blocks out across
/// threads when there are at least `min_serial_rows` rows. Rows are
/// disjoint, so parallel and serial fills write identical bytes.
pub fn par_fill_rows<T, F>(out: &mut [T], row_len: usize, min_serial_rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_fill_rows_scratch(out, row_len, min_serial_rows, || (), |r, row, _| f(r, row));
}

/// Fallible [`par_fill_rows`]: `f` returns `Result<(), E>` per row. The
/// serial path stops at the first failing row. On the parallel path each
/// worker stops its own contiguous block at its first error; after the
/// join, the error with the *smallest row index* is returned, so the
/// reported error is deterministic regardless of chunking. Rows after a
/// failing one may or may not have been filled — callers are expected to
/// abort on error (the training shard does).
pub fn try_par_fill_rows<T, E, F>(
    out: &mut [T],
    row_len: usize,
    min_serial_rows: usize,
    f: F,
) -> Result<(), E>
where
    T: Send,
    E: Send,
    F: Fn(usize, &mut [T]) -> Result<(), E> + Sync,
{
    if row_len == 0 || out.is_empty() {
        return Ok(());
    }
    debug_assert_eq!(out.len() % row_len, 0, "out is not a whole number of rows");
    let n_rows = out.len() / row_len;
    if n_rows < min_serial_rows || threads() <= 1 {
        for (r, row) in out.chunks_mut(row_len).enumerate() {
            f(r, row)?;
        }
        return Ok(());
    }
    let (rows_per, _) = chunking(n_rows);
    let results = spawn_blocks(out, row_len, rows_per, |start, head| {
        for (k, row) in head.chunks_mut(row_len).enumerate() {
            f(start + k, row).map_err(|e| (start + k, e))?;
        }
        Ok(())
    });
    let mut first: Option<(usize, E)> = None;
    for block in results {
        if let Err((r, e)) = block {
            if first.as_ref().map(|(fr, _)| r < *fr).unwrap_or(true) {
                first = Some((r, e));
            }
        }
    }
    match first {
        Some((_, e)) => Err(e),
        None => Ok(()),
    }
}

/// Fill a flat slice in parallel by contiguous chunks: `f(start, seg)`
/// must write every element of `seg` (= `out[start..start + seg.len()]`).
/// Chunks are disjoint, so each output element is computed by exactly one
/// worker — with an index-deterministic `f`, the parallel fill writes
/// bytes identical to `f(0, out)`. Used by the chunked FedAvg: every
/// aggregated coordinate is produced by one worker evaluating the same
/// serial expression.
pub fn par_fill_slice<T, F>(out: &mut [T], min_serial: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    if n < min_serial || threads() <= 1 {
        f(0, out);
        return;
    }
    let (chunk, _) = chunking(n);
    spawn_blocks(out, 1, chunk, |start, head| f(start, head));
}

/// Chunked work-stealing over index ranges — deterministic output at
/// any worker count.
///
/// # Deque layout
///
/// The item set `0..n` is ceil-split into one contiguous range per
/// worker (the same arithmetic as [`chunking`](super::chunking), so the
/// *seed* assignment matches the static splits exactly). Each worker
/// owns a [`RangeDeque`]: its `(head, tail)` pair packed into a single
/// `AtomicU64` (head in the high 32 bits, tail in the low 32). The
/// deque never grows — there is no dynamic spawning, items only drain —
/// which is what makes both the termination check and the exclusivity
/// argument trivial.
///
/// # Steal order
///
/// The owner claims chunks of `grain` items from the **front** of its
/// own deque (preserving ascending index order on the common path, which
/// keeps cache behaviour close to the static split). When its deque is
/// empty it becomes a thief and sweeps the other deques in a fixed ring
/// order (`me+1, me+2, …` mod workers), taking chunks from the **back**
/// of the first non-empty victim — the two ends only collide on the
/// last chunk, where the CAS arbitrates. A worker exits after one full
/// sweep in which every deque (its own included) was empty: ranges only
/// ever shrink, so an all-empty sweep proves there is no work left
/// anywhere.
///
/// Every claim — owner or thief — is a single compare-exchange on the
/// packed word, so **each index in `0..n` is handed to exactly one
/// worker**. That exclusivity is the soundness contract
/// [`SharedUnits`] builds on, and (with canonical reductions — see the
/// [module docs](super)) the reason output is bit-identical at any
/// worker count: *which* worker runs item `i` is timing-dependent,
/// *what* item `i` computes and where it lands is not.
pub mod steal {
    use std::marker::PhantomData;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::thread;

    use crate::util::obs;

    /// Scheduling telemetry from one fan-out. The *output* of a stolen
    /// fan-out is schedule-independent; these counters are not — they
    /// vary run to run with OS timing. Bench JSON records them as the
    /// mechanism evidence (a skewed workload with zero steals means the
    /// scheduler never engaged); nothing correctness-bearing may read
    /// them.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct StealStats {
        /// Workers that actually ran (1 on the inline path).
        pub workers: usize,
        /// Successful steal operations (chunks taken from another
        /// worker's deque).
        pub steals: u64,
        /// Items acquired through those steals.
        pub stolen_items: u64,
    }

    impl StealStats {
        fn serial() -> Self {
            StealStats { workers: 1, steals: 0, stolen_items: 0 }
        }

        /// Fold another fan-out's stats into cumulative telemetry
        /// (per-round counters accumulated across a simulation).
        pub fn absorb(&mut self, other: StealStats) {
            self.workers = self.workers.max(other.workers);
            self.steals += other.steals;
            self.stolen_items += other.stolen_items;
        }
    }

    /// One worker's claimable range: `(head, tail)` packed into a
    /// single atomic word, head high, tail low. `head == tail` means
    /// empty. Indices are `u32` internally — fan-outs are bounded far
    /// below 2^32 items (debug-asserted at the entry point).
    struct RangeDeque {
        ht: AtomicU64,
    }

    fn pack(head: u64, tail: u64) -> u64 {
        (head << 32) | tail
    }

    impl RangeDeque {
        fn new(start: usize, end: usize) -> Self {
            RangeDeque { ht: AtomicU64::new(pack(start as u64, end as u64)) }
        }

        /// Owner side: claim up to `chunk` items from the front.
        fn claim_front(&self, chunk: u64) -> Option<(usize, usize)> {
            let mut cur = self.ht.load(Ordering::Acquire);
            loop {
                let (head, tail) = (cur >> 32, cur & 0xFFFF_FFFF);
                if head >= tail {
                    return None;
                }
                let take = chunk.min(tail - head);
                match self.ht.compare_exchange_weak(
                    cur,
                    pack(head + take, tail),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => return Some((head as usize, (head + take) as usize)),
                    Err(now) => cur = now,
                }
            }
        }

        /// Thief side: claim up to `chunk` items from the back.
        fn steal_back(&self, chunk: u64) -> Option<(usize, usize)> {
            let mut cur = self.ht.load(Ordering::Acquire);
            loop {
                let (head, tail) = (cur >> 32, cur & 0xFFFF_FFFF);
                if head >= tail {
                    return None;
                }
                let take = chunk.min(tail - head);
                match self.ht.compare_exchange_weak(
                    cur,
                    pack(head, tail - take),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => return Some(((tail - take) as usize, tail as usize)),
                    Err(now) => cur = now,
                }
            }
        }
    }

    /// Resolve a caller-supplied worker count: `0` means "auto"
    /// ([`threads`](super::threads), which honours `FEDZERO_THREADS`).
    pub fn resolve_workers(workers: usize) -> usize {
        if workers == 0 {
            super::threads()
        } else {
            workers
        }
    }

    /// Chunk size for a fan-out: small enough that a skewed tail can be
    /// redistributed (~8 chunks per worker), large enough to keep CAS
    /// traffic negligible, capped so huge `n` still steals at a fine
    /// grain relative to per-item cost.
    fn grain(n: usize, workers: usize) -> u64 {
        (n / (workers * 8)).clamp(1, 256) as u64
    }

    /// Run `f(i, &mut state)` for every `i in 0..n` across `workers`
    /// threads (`0` = auto) with work stealing, and return the
    /// per-worker states in worker order plus scheduling telemetry.
    ///
    /// `init(w)` builds worker `w`'s state (scratch buffers, local
    /// reduction accumulators). `f` must be index-deterministic given
    /// any state history: the caller either writes index-owned slots
    /// (via [`SharedUnits`]) or folds into its local state and reduces
    /// canonically after the join — see the [module docs](self) for why
    /// that makes output schedule-independent.
    ///
    /// With `workers <= 1` (or `n <= 1`) this degenerates to the plain
    /// serial loop — same code path the bit-identity tests pin against.
    pub fn steal_exec<S, I, F>(n: usize, workers: usize, init: I, f: F) -> (Vec<S>, StealStats)
    where
        S: Send,
        I: Fn(usize) -> S + Sync,
        F: Fn(usize, &mut S) + Sync,
    {
        let w = resolve_workers(workers).min(n).max(1);
        if n == 0 {
            return (Vec::new(), StealStats::serial());
        }
        if w <= 1 {
            let mut state = init(0);
            for i in 0..n {
                f(i, &mut state);
            }
            return (vec![state], StealStats::serial());
        }
        debug_assert!(n < u32::MAX as usize, "steal_exec index range exceeds u32");
        let chunk = grain(n, w);
        // seed: the same ceil-split as the static `chunking` policy
        let per = (n + w - 1) / w;
        let deques: Vec<RangeDeque> = (0..w)
            .map(|k| RangeDeque::new((k * per).min(n), ((k + 1) * per).min(n)))
            .collect();
        let steals = AtomicU64::new(0);
        let stolen_items = AtomicU64::new(0);
        let states: Vec<S> = thread::scope(|scope| {
            let (deques, init, f) = (&deques, &init, &f);
            let (steals, stolen_items) = (&steals, &stolen_items);
            let handles: Vec<_> = (0..w)
                .map(|me| {
                    scope.spawn(move || {
                        let mut state = init(me);
                        'work: loop {
                            // drain own deque front-to-back
                            while let Some((a, b)) = deques[me].claim_front(chunk) {
                                for i in a..b {
                                    f(i, &mut state);
                                }
                            }
                            // sweep victims in ring order; one full
                            // empty sweep (deques only shrink) == done
                            for d in 1..w {
                                let victim = (me + d) % w;
                                if let Some((a, b)) = deques[victim].steal_back(chunk) {
                                    steals.fetch_add(1, Ordering::Relaxed);
                                    stolen_items.fetch_add((b - a) as u64, Ordering::Relaxed);
                                    for i in a..b {
                                        f(i, &mut state);
                                    }
                                    continue 'work;
                                }
                            }
                            break;
                        }
                        state
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("steal_exec worker panicked"))
                .collect()
        });
        let stats = StealStats {
            workers: w,
            steals: steals.load(Ordering::Relaxed),
            stolen_items: stolen_items.load(Ordering::Relaxed),
        };
        // surface StealStats at every fan-out site, not just in benches
        obs::add(obs::Ctr::StealFanouts, 1);
        obs::add(obs::Ctr::StealSteals, stats.steals);
        obs::add(obs::Ctr::StealStolenItems, stats.stolen_items);
        (states, stats)
    }

    /// Shared mutable view of `out` as disjoint fixed-size units, for
    /// in-place fills where unit *ownership* is decided dynamically by
    /// the scheduler instead of by a static contiguous split (which is
    /// what `spawn_blocks` handles safely with `split_at_mut`).
    ///
    /// This is the one `unsafe` construct in the crate, and its entire
    /// soundness rests on the scheduler's exclusivity guarantee: a
    /// single CAS hands each index to exactly one worker, so no two
    /// threads ever hold `unit(u)` for the same `u`, and no unit is
    /// read while another thread writes it (results are only read after
    /// the scope join, which synchronises via the thread handles).
    pub struct SharedUnits<'a, T> {
        ptr: *mut T,
        n_units: usize,
        unit: usize,
        _marker: PhantomData<&'a mut [T]>,
    }

    // SAFETY: `SharedUnits` only hands out disjoint `&mut [T]` views
    // (caller contract on `unit`), so sharing the wrapper across
    // threads is sound whenever moving the elements themselves would
    // be, i.e. `T: Send`.
    unsafe impl<T: Send> Sync for SharedUnits<'_, T> {}
    unsafe impl<T: Send> Send for SharedUnits<'_, T> {}

    impl<'a, T> SharedUnits<'a, T> {
        /// View `out` as `out.len() / unit` units of `unit` elements.
        pub fn new(out: &'a mut [T], unit: usize) -> Self {
            assert!(unit > 0, "unit must be non-empty");
            debug_assert_eq!(out.len() % unit, 0, "out is not a whole number of units");
            SharedUnits {
                ptr: out.as_mut_ptr(),
                n_units: out.len() / unit,
                unit,
                _marker: PhantomData,
            }
        }

        /// Number of units in the view.
        pub fn len(&self) -> usize {
            self.n_units
        }

        /// Whether the view holds no units.
        pub fn is_empty(&self) -> bool {
            self.n_units == 0
        }

        /// Exclusive view of unit `u`.
        ///
        /// # Safety
        ///
        /// For the lifetime of the returned slice no other call to
        /// `unit(u)` with the same `u` may be live on any thread. Under
        /// [`steal_exec`] this holds by construction when `u` is the
        /// claimed item index (or an injective function of it, e.g. a
        /// `TrainJob`'s strictly-increasing slot): each index is
        /// claimed by exactly one worker, exactly once.
        #[allow(clippy::mut_from_ref)]
        pub unsafe fn unit(&self, u: usize) -> &mut [T] {
            debug_assert!(u < self.n_units, "unit index out of range");
            std::slice::from_raw_parts_mut(self.ptr.add(u * self.unit), self.unit)
        }
    }

    /// Work-stealing counterpart of
    /// [`par_fill_rows_scratch`](super::par_fill_rows_scratch): fill
    /// `out` (length = rows × `row_len`) row by row via
    /// `f(row_index, row_slice, scratch)`, stealing rows across
    /// `workers` threads (`0` = auto) when there are at least
    /// `min_serial_rows` rows. Rows are disjoint and each row index is
    /// claimed exactly once, so parallel and serial fills write
    /// identical bytes; only the telemetry differs. Use where row costs
    /// are skewed (per-domain fills over uneven domain populations).
    pub fn steal_fill_rows_scratch<T, S, I, F>(
        out: &mut [T],
        row_len: usize,
        min_serial_rows: usize,
        workers: usize,
        init: I,
        f: F,
    ) -> StealStats
    where
        T: Send,
        I: Fn() -> S + Sync,
        F: Fn(usize, &mut [T], &mut S) + Sync,
    {
        if row_len == 0 || out.is_empty() {
            return StealStats::serial();
        }
        debug_assert_eq!(out.len() % row_len, 0, "out is not a whole number of rows");
        let n_rows = out.len() / row_len;
        if n_rows < min_serial_rows || resolve_workers(workers) <= 1 {
            let mut scratch = init();
            for (r, row) in out.chunks_mut(row_len).enumerate() {
                f(r, row, &mut scratch);
            }
            return StealStats::serial();
        }
        let shared = SharedUnits::new(out, row_len);
        let shared = &shared;
        let (_, stats) = steal_exec(n_rows, workers, |_| init(), |r, scratch| {
            // SAFETY: steal_exec hands row index `r` to exactly one
            // worker, so this is the only live view of row `r`.
            let row = unsafe { shared.unit(r) };
            f(r, row, scratch);
        });
        stats
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn steal_exec_runs_every_index_exactly_once() {
            for &workers in &[1usize, 2, 3, 8, 64] {
                for &n in &[0usize, 1, 7, 1_000, 10_001] {
                    let (locals, stats) =
                        steal_exec(n, workers, |_| Vec::new(), |i, seen: &mut Vec<usize>| {
                            seen.push(i)
                        });
                    let mut all: Vec<usize> = locals.into_iter().flatten().collect();
                    all.sort_unstable();
                    assert_eq!(all, (0..n).collect::<Vec<_>>(), "w={workers} n={n}");
                    assert!(stats.workers >= 1);
                }
            }
        }

        #[test]
        fn steal_exec_reduction_matches_serial_under_skew() {
            // skewed per-item cost (quadratic spin on a few indices) +
            // order-sensitive float folding: the canonical reduction
            // (index order after the join) must be bit-identical at
            // every worker count
            let n = 4_096usize;
            let work = |i: usize| -> f32 {
                let spin = if i % 511 == 0 { 20_000 } else { 10 };
                let mut acc = i as u64;
                for _ in 0..spin {
                    acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
                }
                ((acc >> 33) as f32) * 1e-9 + (i as f32).sin()
            };
            let run = |workers: usize| -> f32 {
                let (locals, _) = steal_exec(
                    n,
                    workers,
                    |_| Vec::<(usize, f32)>::new(),
                    |i, acc| acc.push((i, work(i))),
                );
                // canonical: scatter by index, then fold ascending
                let mut by_index = vec![0f32; n];
                for (i, v) in locals.into_iter().flatten() {
                    by_index[i] = v;
                }
                by_index.iter().fold(0f32, |s, &v| s + v)
            };
            let serial = run(1);
            for &w in &[2usize, 3, 8] {
                assert_eq!(serial.to_bits(), run(w).to_bits(), "workers={w}");
            }
        }

        #[test]
        fn steal_fill_rows_matches_serial_bytes_with_skewed_rows() {
            let rows = 1_537usize;
            let row_len = 5usize;
            let fill = |r: usize, row: &mut [u64], buf: &mut Vec<u64>| {
                // row cost skew: one monster row, the rest trivial
                let reps = if r == 3 { 50_000 } else { r % 7 + 1 };
                buf.clear();
                buf.extend((0..reps as u64).map(|k| k.wrapping_mul(0x9E37) ^ r as u64));
                let tag = buf.iter().fold(0u64, |a, &b| a.wrapping_add(b));
                for (j, v) in row.iter_mut().enumerate() {
                    *v = tag ^ ((r * 31 + j) as u64);
                }
            };
            let mut serial = vec![0u64; rows * row_len];
            {
                let mut buf = Vec::new();
                for (r, row) in serial.chunks_mut(row_len).enumerate() {
                    fill(r, row, &mut buf);
                }
            }
            for &w in &[1usize, 2, 8] {
                let mut stolen = vec![0u64; rows * row_len];
                let stats =
                    steal_fill_rows_scratch(&mut stolen, row_len, 0, w, Vec::new, fill);
                assert_eq!(serial, stolen, "workers={w}");
                assert_eq!(stats.workers, w.min(rows).max(1));
            }
        }

        #[test]
        fn steal_fill_rows_serial_threshold_and_empty() {
            let mut out: Vec<u32> = vec![0; 12];
            let stats = steal_fill_rows_scratch(&mut out, 3, usize::MAX, 8, || (), |r, row, _| {
                for v in row.iter_mut() {
                    *v = r as u32;
                }
            });
            assert_eq!(out, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3, 3]);
            assert_eq!(stats, StealStats::serial());
            let mut empty: Vec<u32> = Vec::new();
            let stats = steal_fill_rows_scratch(&mut empty, 4, 0, 8, || (), |_, _, _| {});
            assert_eq!(stats, StealStats::serial());
        }

        #[test]
        fn deque_claim_and_steal_partition_the_range() {
            let d = RangeDeque::new(10, 50);
            let mut got = Vec::new();
            // interleave owner claims and thief steals
            loop {
                let a = d.claim_front(3);
                let b = d.steal_back(5);
                if a.is_none() && b.is_none() {
                    break;
                }
                for (x, y) in a.into_iter().chain(b) {
                    assert!(x < y);
                    got.extend(x..y);
                }
            }
            got.sort_unstable();
            assert_eq!(got, (10..50).collect::<Vec<_>>());
        }

        #[test]
        fn stats_absorb_accumulates() {
            let mut total = StealStats::serial();
            total.absorb(StealStats { workers: 4, steals: 3, stolen_items: 17 });
            total.absorb(StealStats { workers: 2, steals: 1, stolen_items: 2 });
            assert_eq!(total, StealStats { workers: 4, steals: 4, stolen_items: 19 });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial_map() {
        let serial: Vec<u64> = (0..10_000).map(|i| (i as u64).wrapping_mul(0x9E37)).collect();
        // force the parallel path with min_serial = 0
        let parallel = par_map(10_000, 0, |i| (i as u64).wrapping_mul(0x9E37));
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_map_below_threshold_runs_inline() {
        let out = par_map(5, 1_000, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, 0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn par_map_scratch_matches_serial_and_reuses_buffers() {
        // scratch is a reusable buffer; output must equal the plain map
        // regardless of chunking
        let compute = |i: usize, buf: &mut Vec<u64>| -> u64 {
            buf.clear();
            buf.extend((0..=i as u64 % 7).map(|k| k * 3));
            buf.iter().sum::<u64>() + i as u64
        };
        let serial: Vec<u64> = {
            let mut buf = Vec::new();
            (0..5_000).map(|i| compute(i, &mut buf)).collect()
        };
        let parallel = par_map_scratch(5_000, 0, Vec::new, compute);
        assert_eq!(serial, parallel);
        let inline = par_map_scratch(5_000, 1_000_000, Vec::new, compute);
        assert_eq!(serial, inline);
    }

    #[test]
    fn par_fill_rows_matches_serial_fill() {
        let rows = 513usize;
        let row_len = 7usize;
        let fill = |r: usize, row: &mut [u64]| {
            for (j, v) in row.iter_mut().enumerate() {
                *v = (r as u64) * 1_000 + j as u64;
            }
        };
        let mut serial = vec![0u64; rows * row_len];
        for (r, row) in serial.chunks_mut(row_len).enumerate() {
            fill(r, row);
        }
        let mut parallel = vec![0u64; rows * row_len];
        par_fill_rows(&mut parallel, row_len, 0, fill);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_fill_rows_scratch_reuses_row_capacity_in_place() {
        // rows are owned Vecs refilled in place (the engine's grant
        // pattern): contents must match the serial fill and survive
        // arbitrary chunking
        let n = 257usize;
        let fill = |r: usize, row: &mut [Vec<usize>], buf: &mut Vec<usize>| {
            buf.clear();
            buf.extend(0..r % 5);
            row[0].clear();
            row[0].extend(buf.iter().map(|&x| x + r));
        };
        let mut serial: Vec<Vec<usize>> = vec![Vec::new(); n];
        {
            let mut buf = Vec::new();
            for r in 0..n {
                fill(r, &mut serial[r..r + 1], &mut buf);
            }
        }
        let mut parallel: Vec<Vec<usize>> = vec![Vec::new(); n];
        par_fill_rows_scratch(&mut parallel, 1, 0, Vec::new, fill);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn try_par_fill_rows_ok_matches_serial() {
        let rows = 301usize;
        let fill = |r: usize, row: &mut [u64]| -> Result<(), String> {
            row[0] = (r as u64).wrapping_mul(0xABCD) ^ 7;
            Ok(())
        };
        let mut serial = vec![0u64; rows];
        for (r, row) in serial.chunks_mut(1).enumerate() {
            fill(r, row).unwrap();
        }
        let mut parallel = vec![0u64; rows];
        try_par_fill_rows(&mut parallel, 1, 0, fill).unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn try_par_fill_rows_reports_smallest_failing_row() {
        // several failing rows spread over different worker blocks: the
        // returned error must always be the smallest row index
        let rows = 512usize;
        let err = try_par_fill_rows(
            &mut vec![0u8; rows],
            1,
            0,
            |r, _row: &mut [u8]| -> Result<(), usize> {
                if r % 100 == 37 {
                    Err(r)
                } else {
                    Ok(())
                }
            },
        )
        .unwrap_err();
        assert_eq!(err, 37);
        // serial path (threshold above row count) agrees
        let err_serial = try_par_fill_rows(
            &mut vec![0u8; rows],
            1,
            usize::MAX,
            |r, _row: &mut [u8]| -> Result<(), usize> {
                if r % 100 == 37 {
                    Err(r)
                } else {
                    Ok(())
                }
            },
        )
        .unwrap_err();
        assert_eq!(err_serial, 37);
    }

    #[test]
    fn par_fill_slice_matches_serial_fill() {
        let n = 10_007usize;
        let fill = |start: usize, seg: &mut [u64]| {
            for (j, v) in seg.iter_mut().enumerate() {
                *v = ((start + j) as u64).wrapping_mul(0x9E3779B9);
            }
        };
        let mut serial = vec![0u64; n];
        fill(0, &mut serial);
        let mut parallel = vec![0u64; n];
        par_fill_slice(&mut parallel, 0, fill);
        assert_eq!(serial, parallel);
        let mut inline = vec![0u64; n];
        par_fill_slice(&mut inline, usize::MAX, fill);
        assert_eq!(serial, inline);
    }

    #[test]
    fn threads_override_parses_only_positive_integers() {
        assert_eq!(parse_threads_override("4"), Some(4));
        assert_eq!(parse_threads_override(" 16 "), Some(16));
        assert_eq!(parse_threads_override("1"), Some(1));
        assert_eq!(parse_threads_override("0"), None);
        assert_eq!(parse_threads_override(""), None);
        assert_eq!(parse_threads_override("auto"), None);
        assert_eq!(parse_threads_override("-2"), None);
    }

    #[test]
    fn par_ranges_covers_everything_once() {
        let ranges = par_ranges(10_001, 0, |a, b| (a, b));
        let mut expect = 0usize;
        for (a, b) in ranges {
            assert_eq!(a, expect, "gap or overlap at {a}");
            assert!(b >= a);
            expect = b;
        }
        assert_eq!(expect, 10_001);
    }

    #[test]
    fn par_ranges_reduces_deterministically() {
        // best-index reduction as used by the swap scan: max value, ties
        // to the lowest index — identical regardless of chunking
        let vals: Vec<f64> = (0..5_000).map(|i| ((i * 37) % 1000) as f64).collect();
        let pick = |parts: Vec<Option<(f64, usize)>>| -> Option<(f64, usize)> {
            let mut best: Option<(f64, usize)> = None;
            for p in parts.into_iter().flatten() {
                if best.map(|(b, _)| p.0 > b).unwrap_or(true) {
                    best = Some(p);
                }
            }
            best
        };
        let scan = |a: usize, b: usize| -> Option<(f64, usize)> {
            let mut best: Option<(f64, usize)> = None;
            for i in a..b {
                if best.map(|(b, _)| vals[i] > b).unwrap_or(true) {
                    best = Some((vals[i], i));
                }
            }
            best
        };
        let serial = pick(vec![scan(0, vals.len())]);
        let parallel = pick(par_ranges(vals.len(), 0, scan));
        assert_eq!(serial, parallel);
    }
}
