//! Minimal fork-join parallelism on `std::thread::scope`.
//!
//! The offline vendor set has no `rayon`, so the selection pipeline's
//! data-parallel stages (arena construction, standalone scoring, swap
//! candidate scanning) use this instead: deterministic chunked fan-out
//! with results merged in index order, so parallel and sequential
//! execution produce bit-identical output. Every entry point takes a
//! `min_serial` threshold below which it runs inline — the unit-test and
//! evaluation-scale instances never pay thread-spawn overhead.

use std::thread;

/// Number of worker threads to fan out to (>= 1).
pub fn threads() -> usize {
    thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
}

/// `(0..n).map(f)` collected in order, chunked across threads when
/// `n >= min_serial` and more than one core is available. `f` must be
/// index-deterministic: the output is identical to the serial map.
pub fn par_map<T, F>(n: usize, min_serial: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads();
    if n == 0 || n < min_serial || workers <= 1 {
        return (0..n).map(f).collect();
    }
    let workers = workers.min(n);
    let chunk = (n + workers - 1) / workers;
    // ceil(n/chunk) chunks, so every chunk is non-empty even when
    // workers*chunk overshoots n (many-core hosts, small n)
    let n_chunks = (n + chunk - 1) / chunk;
    let mut out: Vec<T> = Vec::with_capacity(n);
    let parts: Vec<Vec<T>> = thread::scope(|s| {
        let handles: Vec<_> = (0..n_chunks)
            .map(|k| {
                let f = &f;
                s.spawn(move || {
                    let start = k * chunk;
                    let end = ((k + 1) * chunk).min(n);
                    (start..end).map(f).collect::<Vec<T>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map worker panicked"))
            .collect()
    });
    for part in parts {
        out.extend(part);
    }
    out
}

/// Split `0..n` into contiguous ranges, run `f(start, end)` on each (in
/// parallel when `n >= min_serial`), and return the per-range results in
/// range order. Lets callers keep per-thread scratch state inside `f`.
pub fn par_ranges<T, F>(n: usize, min_serial: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    let workers = threads();
    if n == 0 {
        return Vec::new();
    }
    if n < min_serial || workers <= 1 {
        return vec![f(0, n)];
    }
    let workers = workers.min(n);
    let chunk = (n + workers - 1) / workers;
    let n_chunks = (n + chunk - 1) / chunk;
    thread::scope(|s| {
        let handles: Vec<_> = (0..n_chunks)
            .map(|k| {
                let f = &f;
                s.spawn(move || {
                    let start = k * chunk;
                    let end = ((k + 1) * chunk).min(n);
                    f(start, end)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_ranges worker panicked"))
            .collect()
    })
}

/// Fill `out` (length = rows × `row_len`) row by row via
/// `f(row_index, row_slice)`, fanning contiguous row blocks out across
/// threads when there are at least `min_serial_rows` rows. Rows are
/// disjoint, so parallel and serial fills write identical bytes.
pub fn par_fill_rows<T, F>(out: &mut [T], row_len: usize, min_serial_rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if row_len == 0 || out.is_empty() {
        return;
    }
    debug_assert_eq!(out.len() % row_len, 0, "out is not a whole number of rows");
    let n_rows = out.len() / row_len;
    let workers = threads();
    if n_rows < min_serial_rows || workers <= 1 {
        for (r, row) in out.chunks_mut(row_len).enumerate() {
            f(r, row);
        }
        return;
    }
    let workers = workers.min(n_rows);
    let rows_per = (n_rows + workers - 1) / workers;
    thread::scope(|s| {
        let f = &f;
        let mut handles = Vec::new();
        let mut rest: &mut [T] = out;
        let mut r0 = 0usize;
        while r0 < n_rows {
            let take = rows_per.min(n_rows - r0);
            let tmp = std::mem::take(&mut rest);
            let (head, tail) = tmp.split_at_mut(take * row_len);
            rest = tail;
            let start = r0;
            handles.push(s.spawn(move || {
                for (k, row) in head.chunks_mut(row_len).enumerate() {
                    f(start + k, row);
                }
            }));
            r0 += take;
        }
        for h in handles {
            h.join().expect("par_fill_rows worker panicked");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial_map() {
        let serial: Vec<u64> = (0..10_000).map(|i| (i as u64).wrapping_mul(0x9E37)).collect();
        // force the parallel path with min_serial = 0
        let parallel = par_map(10_000, 0, |i| (i as u64).wrapping_mul(0x9E37));
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_map_below_threshold_runs_inline() {
        let out = par_map(5, 1_000, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, 0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn par_fill_rows_matches_serial_fill() {
        let rows = 513usize;
        let row_len = 7usize;
        let fill = |r: usize, row: &mut [u64]| {
            for (j, v) in row.iter_mut().enumerate() {
                *v = (r as u64) * 1_000 + j as u64;
            }
        };
        let mut serial = vec![0u64; rows * row_len];
        for (r, row) in serial.chunks_mut(row_len).enumerate() {
            fill(r, row);
        }
        let mut parallel = vec![0u64; rows * row_len];
        par_fill_rows(&mut parallel, row_len, 0, fill);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_ranges_covers_everything_once() {
        let ranges = par_ranges(10_001, 0, |a, b| (a, b));
        let mut expect = 0usize;
        for (a, b) in ranges {
            assert_eq!(a, expect, "gap or overlap at {a}");
            assert!(b >= a);
            expect = b;
        }
        assert_eq!(expect, 10_001);
    }

    #[test]
    fn par_ranges_reduces_deterministically() {
        // best-index reduction as used by the swap scan: max value, ties
        // to the lowest index — identical regardless of chunking
        let vals: Vec<f64> = (0..5_000).map(|i| ((i * 37) % 1000) as f64).collect();
        let pick = |parts: Vec<Option<(f64, usize)>>| -> Option<(f64, usize)> {
            let mut best: Option<(f64, usize)> = None;
            for p in parts.into_iter().flatten() {
                if best.map(|(b, _)| p.0 > b).unwrap_or(true) {
                    best = Some(p);
                }
            }
            best
        };
        let scan = |a: usize, b: usize| -> Option<(f64, usize)> {
            let mut best: Option<(f64, usize)> = None;
            for i in a..b {
                if best.map(|(b, _)| vals[i] > b).unwrap_or(true) {
                    best = Some((vals[i], i));
                }
            }
            best
        };
        let serial = pick(vec![scan(0, vals.len())]);
        let parallel = pick(par_ranges(vals.len(), 0, scan));
        assert_eq!(serial, parallel);
    }
}
