//! Minimal fork-join parallelism on `std::thread::scope`.
//!
//! The offline vendor set has no `rayon`, so the selection pipeline's
//! data-parallel stages (arena construction, standalone scoring, swap
//! candidate scanning, per-domain round execution) use this instead:
//! deterministic chunked fan-out with results merged in index order, so
//! parallel and sequential execution produce bit-identical output. Every
//! entry point takes a `min_serial` threshold below which it runs inline
//! — the unit-test and evaluation-scale instances never pay thread-spawn
//! overhead.
//!
//! Two internals own the fan-out policy — `chunking` (how many units per
//! worker) and `spawn_blocks` (the split-and-spawn walk every in-place
//! fill funnels through); [`par_ranges`] owns the collect-style maps.
//! Everything else ([`par_map`], [`par_fill_rows`], [`try_par_fill_rows`],
//! [`par_fill_slice`], ...) is a thin wrapper, so a change to the
//! worker/chunk computation cannot silently diverge between callers.

use std::thread;

/// The ONE table of fan-out thresholds for every parallel stage in the
/// crate (satellite: these used to be duplicated per module —
/// `PAR_MIN_ROWS` in `selection::{ring,arena}`, `PAR_MIN_*` in
/// `solver::mip` — and could drift apart silently). Below a threshold
/// the stage runs inline; results are bit-identical either way, so these
/// are pure performance knobs: thread spawn/join costs a few µs, which
/// only pays off once a stage has enough independent work.
pub mod thresholds {
    /// Rows below which in-place row fills stay single-threaded (ring
    /// rebuild/advance/catch-up, arena reachability fills). One row is a
    /// handful of float writes, so fan-out needs thousands of them.
    pub const MIN_FILL_ROWS: usize = 2048;
    /// Candidate counts below which per-client map stages stay serial
    /// (standalone scoring, swap-candidate scans).
    pub const MIN_CLIENTS: usize = 4096;
    /// Domain-group counts below which per-domain evaluation stays
    /// serial (groups are tiny flow solves; see `MIN_EVAL_WORK`).
    pub const MIN_DOMAIN_GROUPS: usize = 16;
    /// `chosen·steps` product below which `evaluate_view` stays serial —
    /// branch-and-bound calls it on every node, where spawn/join would
    /// dwarf a handful of tiny flow solves.
    pub const MIN_EVAL_WORK: usize = 8192;
    /// Candidate count at which the exact solver fans independent root
    /// subtrees out across workers (small instances finish faster than
    /// the frontier split costs).
    pub const BNB_MIN_CLIENTS: usize = 64;
    /// Dirty-client count at which `IncrSelState::advance` fans its
    /// reach re-derivation walks out across workers (each walk is an
    /// O(√d_max) read-only fold; the counter/append phase and the
    /// reach/counter application stay serial either way).
    pub const REDERIVE_CLIENTS: usize = 4096;
    /// Engine round execution: minimum domains spanned by a round before
    /// the per-domain grant computation fans out…
    pub const ROUND_DOMAINS: usize = 8;
    /// …AND minimum selected clients (both gates must pass; water-filling
    /// a few slots is cheaper than a spawn).
    pub const ROUND_SLOTS: usize = 256;
    /// Hierarchical aggregation: minimum domain groups in a round before
    /// the per-domain partial fills fan out…
    pub const TREE_GROUPS: usize = 8;
    /// …AND minimum total work (participants × parameters; both gates
    /// must pass — a few small partial rows fill faster inline).
    pub const TREE_WORK: usize = 1 << 15;
}

/// Number of worker threads to fan out to (>= 1).
pub fn threads() -> usize {
    thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
}

/// The shared chunking policy: ceil-split `n` items over the available
/// workers so every chunk is non-empty (many-core hosts, small n).
/// Returns (chunk_size, n_chunks).
fn chunking(n: usize) -> (usize, usize) {
    let workers = threads().min(n).max(1);
    let chunk = (n + workers - 1) / workers;
    let n_chunks = (n + chunk - 1) / chunk;
    (chunk, n_chunks)
}

/// The shared block-spawn walk for every in-place parallel fill: split
/// `out` — interpreted as `out.len() / unit` units of `unit` elements —
/// into contiguous blocks of `units_per_block` units, run
/// `f(first_unit_index, block)` on each block in its own scoped thread,
/// and return the per-block results in block order. All mutable-fill
/// entry points funnel through here so the split arithmetic cannot
/// silently diverge between them (the same promise [`chunking`] makes
/// for chunk sizing).
fn spawn_blocks<T, R, F>(
    out: &mut [T],
    unit: usize,
    units_per_block: usize,
    f: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut [T]) -> R + Sync,
{
    let n_units = out.len() / unit;
    thread::scope(|s| {
        let f = &f;
        let mut handles = Vec::new();
        let mut rest: &mut [T] = out;
        let mut u0 = 0usize;
        while u0 < n_units {
            let take = units_per_block.min(n_units - u0);
            let tmp = std::mem::take(&mut rest);
            let (head, tail) = tmp.split_at_mut(take * unit);
            rest = tail;
            let start = u0;
            handles.push(s.spawn(move || f(start, head)));
            u0 += take;
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("spawn_blocks worker panicked"))
            .collect()
    })
}

/// Split `0..n` into contiguous ranges, run `f(start, end)` on each (in
/// parallel when `n >= min_serial`), and return the per-range results in
/// range order. Lets callers keep per-thread scratch state inside `f`.
/// This is the core primitive every map-style wrapper builds on.
pub fn par_ranges<T, F>(n: usize, min_serial: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    if n < min_serial || threads() <= 1 {
        return vec![f(0, n)];
    }
    let (chunk, n_chunks) = chunking(n);
    thread::scope(|s| {
        let handles: Vec<_> = (0..n_chunks)
            .map(|k| {
                let f = &f;
                s.spawn(move || {
                    let start = k * chunk;
                    let end = ((k + 1) * chunk).min(n);
                    f(start, end)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_ranges worker panicked"))
            .collect()
    })
}

/// [`par_map`] with per-worker scratch state: `init()` builds one scratch
/// per worker (or one total on the serial path), and `f(i, scratch)` may
/// mutate it freely between calls. `f` must be index-deterministic given
/// *any* scratch state (scratch is reuse-only — buffers, workspaces), so
/// the output is identical to the serial map.
pub fn par_map_scratch<T, S, I, F>(n: usize, min_serial: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> T + Sync,
{
    let parts = par_ranges(n, min_serial, |start, end| {
        let mut scratch = init();
        (start..end).map(|i| f(i, &mut scratch)).collect::<Vec<T>>()
    });
    let mut out = Vec::with_capacity(n);
    for part in parts {
        out.extend(part);
    }
    out
}

/// `(0..n).map(f)` collected in order, chunked across threads when
/// `n >= min_serial` and more than one core is available. `f` must be
/// index-deterministic: the output is identical to the serial map.
pub fn par_map<T, F>(n: usize, min_serial: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_scratch(n, min_serial, || (), |i, _| f(i))
}

/// [`par_fill_rows`] with per-worker scratch state (same contract as
/// [`par_map_scratch`]): fill `out` (length = rows × `row_len`) row by
/// row via `f(row_index, row_slice, scratch)`, fanning contiguous row
/// blocks out across threads when there are at least `min_serial_rows`
/// rows. Rows are disjoint, so parallel and serial fills write identical
/// bytes. Used by the simulation engine to recompute per-domain grant
/// rows in place — the row buffers keep their capacity across steps and
/// the request/active scratch is reused within each worker.
pub fn par_fill_rows_scratch<T, S, I, F>(
    out: &mut [T],
    row_len: usize,
    min_serial_rows: usize,
    init: I,
    f: F,
) where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut [T], &mut S) + Sync,
{
    if row_len == 0 || out.is_empty() {
        return;
    }
    debug_assert_eq!(out.len() % row_len, 0, "out is not a whole number of rows");
    let n_rows = out.len() / row_len;
    if n_rows < min_serial_rows || threads() <= 1 {
        let mut scratch = init();
        for (r, row) in out.chunks_mut(row_len).enumerate() {
            f(r, row, &mut scratch);
        }
        return;
    }
    let (rows_per, _) = chunking(n_rows);
    spawn_blocks(out, row_len, rows_per, |start, head| {
        let mut scratch = init();
        for (k, row) in head.chunks_mut(row_len).enumerate() {
            f(start + k, row, &mut scratch);
        }
    });
}

/// Fill `out` (length = rows × `row_len`) row by row via
/// `f(row_index, row_slice)`, fanning contiguous row blocks out across
/// threads when there are at least `min_serial_rows` rows. Rows are
/// disjoint, so parallel and serial fills write identical bytes.
pub fn par_fill_rows<T, F>(out: &mut [T], row_len: usize, min_serial_rows: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_fill_rows_scratch(out, row_len, min_serial_rows, || (), |r, row, _| f(r, row));
}

/// Fallible [`par_fill_rows`]: `f` returns `Result<(), E>` per row. The
/// serial path stops at the first failing row. On the parallel path each
/// worker stops its own contiguous block at its first error; after the
/// join, the error with the *smallest row index* is returned, so the
/// reported error is deterministic regardless of chunking. Rows after a
/// failing one may or may not have been filled — callers are expected to
/// abort on error (the training shard does).
pub fn try_par_fill_rows<T, E, F>(
    out: &mut [T],
    row_len: usize,
    min_serial_rows: usize,
    f: F,
) -> Result<(), E>
where
    T: Send,
    E: Send,
    F: Fn(usize, &mut [T]) -> Result<(), E> + Sync,
{
    if row_len == 0 || out.is_empty() {
        return Ok(());
    }
    debug_assert_eq!(out.len() % row_len, 0, "out is not a whole number of rows");
    let n_rows = out.len() / row_len;
    if n_rows < min_serial_rows || threads() <= 1 {
        for (r, row) in out.chunks_mut(row_len).enumerate() {
            f(r, row)?;
        }
        return Ok(());
    }
    let (rows_per, _) = chunking(n_rows);
    let results = spawn_blocks(out, row_len, rows_per, |start, head| {
        for (k, row) in head.chunks_mut(row_len).enumerate() {
            f(start + k, row).map_err(|e| (start + k, e))?;
        }
        Ok(())
    });
    let mut first: Option<(usize, E)> = None;
    for block in results {
        if let Err((r, e)) = block {
            if first.as_ref().map(|(fr, _)| r < *fr).unwrap_or(true) {
                first = Some((r, e));
            }
        }
    }
    match first {
        Some((_, e)) => Err(e),
        None => Ok(()),
    }
}

/// Fill a flat slice in parallel by contiguous chunks: `f(start, seg)`
/// must write every element of `seg` (= `out[start..start + seg.len()]`).
/// Chunks are disjoint, so each output element is computed by exactly one
/// worker — with an index-deterministic `f`, the parallel fill writes
/// bytes identical to `f(0, out)`. Used by the chunked FedAvg: every
/// aggregated coordinate is produced by one worker evaluating the same
/// serial expression.
pub fn par_fill_slice<T, F>(out: &mut [T], min_serial: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = out.len();
    if n == 0 {
        return;
    }
    if n < min_serial || threads() <= 1 {
        f(0, out);
        return;
    }
    let (chunk, _) = chunking(n);
    spawn_blocks(out, 1, chunk, |start, head| f(start, head));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial_map() {
        let serial: Vec<u64> = (0..10_000).map(|i| (i as u64).wrapping_mul(0x9E37)).collect();
        // force the parallel path with min_serial = 0
        let parallel = par_map(10_000, 0, |i| (i as u64).wrapping_mul(0x9E37));
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_map_below_threshold_runs_inline() {
        let out = par_map(5, 1_000, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, 0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn par_map_scratch_matches_serial_and_reuses_buffers() {
        // scratch is a reusable buffer; output must equal the plain map
        // regardless of chunking
        let compute = |i: usize, buf: &mut Vec<u64>| -> u64 {
            buf.clear();
            buf.extend((0..=i as u64 % 7).map(|k| k * 3));
            buf.iter().sum::<u64>() + i as u64
        };
        let serial: Vec<u64> = {
            let mut buf = Vec::new();
            (0..5_000).map(|i| compute(i, &mut buf)).collect()
        };
        let parallel = par_map_scratch(5_000, 0, Vec::new, compute);
        assert_eq!(serial, parallel);
        let inline = par_map_scratch(5_000, 1_000_000, Vec::new, compute);
        assert_eq!(serial, inline);
    }

    #[test]
    fn par_fill_rows_matches_serial_fill() {
        let rows = 513usize;
        let row_len = 7usize;
        let fill = |r: usize, row: &mut [u64]| {
            for (j, v) in row.iter_mut().enumerate() {
                *v = (r as u64) * 1_000 + j as u64;
            }
        };
        let mut serial = vec![0u64; rows * row_len];
        for (r, row) in serial.chunks_mut(row_len).enumerate() {
            fill(r, row);
        }
        let mut parallel = vec![0u64; rows * row_len];
        par_fill_rows(&mut parallel, row_len, 0, fill);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn par_fill_rows_scratch_reuses_row_capacity_in_place() {
        // rows are owned Vecs refilled in place (the engine's grant
        // pattern): contents must match the serial fill and survive
        // arbitrary chunking
        let n = 257usize;
        let fill = |r: usize, row: &mut [Vec<usize>], buf: &mut Vec<usize>| {
            buf.clear();
            buf.extend(0..r % 5);
            row[0].clear();
            row[0].extend(buf.iter().map(|&x| x + r));
        };
        let mut serial: Vec<Vec<usize>> = vec![Vec::new(); n];
        {
            let mut buf = Vec::new();
            for r in 0..n {
                fill(r, &mut serial[r..r + 1], &mut buf);
            }
        }
        let mut parallel: Vec<Vec<usize>> = vec![Vec::new(); n];
        par_fill_rows_scratch(&mut parallel, 1, 0, Vec::new, fill);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn try_par_fill_rows_ok_matches_serial() {
        let rows = 301usize;
        let fill = |r: usize, row: &mut [u64]| -> Result<(), String> {
            row[0] = (r as u64).wrapping_mul(0xABCD) ^ 7;
            Ok(())
        };
        let mut serial = vec![0u64; rows];
        for (r, row) in serial.chunks_mut(1).enumerate() {
            fill(r, row).unwrap();
        }
        let mut parallel = vec![0u64; rows];
        try_par_fill_rows(&mut parallel, 1, 0, fill).unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn try_par_fill_rows_reports_smallest_failing_row() {
        // several failing rows spread over different worker blocks: the
        // returned error must always be the smallest row index
        let rows = 512usize;
        let err = try_par_fill_rows(
            &mut vec![0u8; rows],
            1,
            0,
            |r, _row: &mut [u8]| -> Result<(), usize> {
                if r % 100 == 37 {
                    Err(r)
                } else {
                    Ok(())
                }
            },
        )
        .unwrap_err();
        assert_eq!(err, 37);
        // serial path (threshold above row count) agrees
        let err_serial = try_par_fill_rows(
            &mut vec![0u8; rows],
            1,
            usize::MAX,
            |r, _row: &mut [u8]| -> Result<(), usize> {
                if r % 100 == 37 {
                    Err(r)
                } else {
                    Ok(())
                }
            },
        )
        .unwrap_err();
        assert_eq!(err_serial, 37);
    }

    #[test]
    fn par_fill_slice_matches_serial_fill() {
        let n = 10_007usize;
        let fill = |start: usize, seg: &mut [u64]| {
            for (j, v) in seg.iter_mut().enumerate() {
                *v = ((start + j) as u64).wrapping_mul(0x9E3779B9);
            }
        };
        let mut serial = vec![0u64; n];
        fill(0, &mut serial);
        let mut parallel = vec![0u64; n];
        par_fill_slice(&mut parallel, 0, fill);
        assert_eq!(serial, parallel);
        let mut inline = vec![0u64; n];
        par_fill_slice(&mut inline, usize::MAX, fill);
        assert_eq!(serial, inline);
    }

    #[test]
    fn par_ranges_covers_everything_once() {
        let ranges = par_ranges(10_001, 0, |a, b| (a, b));
        let mut expect = 0usize;
        for (a, b) in ranges {
            assert_eq!(a, expect, "gap or overlap at {a}");
            assert!(b >= a);
            expect = b;
        }
        assert_eq!(expect, 10_001);
    }

    #[test]
    fn par_ranges_reduces_deterministically() {
        // best-index reduction as used by the swap scan: max value, ties
        // to the lowest index — identical regardless of chunking
        let vals: Vec<f64> = (0..5_000).map(|i| ((i * 37) % 1000) as f64).collect();
        let pick = |parts: Vec<Option<(f64, usize)>>| -> Option<(f64, usize)> {
            let mut best: Option<(f64, usize)> = None;
            for p in parts.into_iter().flatten() {
                if best.map(|(b, _)| p.0 > b).unwrap_or(true) {
                    best = Some(p);
                }
            }
            best
        };
        let scan = |a: usize, b: usize| -> Option<(f64, usize)> {
            let mut best: Option<(f64, usize)> = None;
            for i in a..b {
                if best.map(|(b, _)| vals[i] > b).unwrap_or(true) {
                    best = Some((vals[i], i));
                }
            }
            best
        };
        let serial = pick(vec![scan(0, vals.len())]);
        let parallel = pick(par_ranges(vals.len(), 0, scan));
        assert_eq!(serial, parallel);
    }
}
