//! Descriptive statistics used across metrics, benches, and trace models.

/// Streaming mean/variance (Welford) — numerically stable for the long
/// per-step accumulations in the simulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// Linear-interpolated percentile. `q` is clamped to [0, 100], so
/// q=0 is the minimum and q=100 the maximum; a single-element slice
/// returns that element for every q. Empty input returns NaN (there is
/// no sensible number). NaN *elements* sort last (`total_cmp`) instead
/// of panicking.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let q = q.clamp(0.0, 100.0);
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Jain's fairness index: (Σx)² / (n·Σx²) — 1.0 for a perfectly even
/// allocation, 1/n for a single-winner one; 0.0 for the empty/all-zero
/// case. Used by the campaign report to summarise participation shares.
pub fn jain(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        0.0
    } else {
        sum * sum / (xs.len() as f64 * sq)
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Fixed-width histogram for round-duration distributions (Fig 7 right).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let nbins = self.counts.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * nbins as f64) as usize;
            self.counts[idx.min(nbins - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Render an ASCII sparkline of the bin occupancies.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1);
        self.counts
            .iter()
            .map(|&c| GLYPHS[(c * 7 / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_edges() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.0, 0.5, 5.0, 9.99, -1.0, 10.0, 100.0] {
            h.push(x);
        }
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
        assert_eq!(h.total(), 7);
        assert_eq!(h.sparkline().chars().count(), 10);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_edge_cases() {
        // single element: every q returns it
        for q in [-5.0, 0.0, 37.2, 100.0, 250.0] {
            assert_eq!(percentile(&[42.0], q), 42.0);
        }
        // out-of-range q clamps to the endpoints
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, -10.0), 1.0);
        assert_eq!(percentile(&xs, 1e9), 5.0);
        // NaN elements sort last rather than panicking
        let with_nan = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&with_nan, 0.0), 1.0);
        assert_eq!(percentile(&with_nan, 50.0), 2.0);
    }

    #[test]
    fn percentile_matches_sorted_index_oracle() {
        use crate::util::prop::forall;
        forall(200, |rng| {
            let n = 1 + (rng.next_u64() % 40) as usize;
            let xs: Vec<f64> =
                (0..n).map(|_| (rng.f64() * 2000.0) - 1000.0).collect();
            let mut sorted = xs.clone();
            sorted.sort_by(f64::total_cmp);
            // exact rank points hit the sorted element exactly
            for (i, &s) in sorted.iter().enumerate() {
                let q = 100.0 * i as f64 / (n - 1).max(1) as f64;
                let p = percentile(&xs, q);
                assert!(
                    (p - s).abs() < 1e-9,
                    "rank {i}/{n} q={q}: got {p}, oracle {s}"
                );
            }
            // arbitrary q is monotone and bracketed by neighbours
            let q = rng.f64() * 100.0;
            let p = percentile(&xs, q);
            let pos = q / 100.0 * (n - 1) as f64;
            let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
            assert!(
                sorted[lo] - 1e-9 <= p && p <= sorted[hi] + 1e-9,
                "q={q}: {p} outside [{}, {}]",
                sorted[lo],
                sorted[hi]
            );
        });
    }

    #[test]
    fn jain_index_bounds() {
        assert_eq!(jain(&[]), 0.0);
        assert_eq!(jain(&[0.0, 0.0]), 0.0);
        assert!((jain(&[3.0, 3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
        assert!((jain(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        let mid = jain(&[4.0, 2.0, 1.0, 1.0]);
        assert!(mid > 0.25 && mid < 1.0, "jain {mid}");
    }
}
