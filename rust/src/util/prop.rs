//! Property-testing harness (offline substitute for proptest).
//!
//! `forall(cases, |rng| ...)` runs a closure over many seeded RNGs; a
//! failing case panics with the seed so it can be replayed exactly with
//! `replay(seed, f)`. No shrinking — generators here draw small sizes to
//! keep counterexamples readable.

use super::rng::Rng;

/// Run `f` for `cases` deterministic seeds (0..cases), panicking with the
/// seed of the first failing case.
pub fn forall<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: u64, f: F) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(0xF00D ^ seed.wrapping_mul(0x9E3779B9));
            f(&mut rng);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case seed={seed}: {msg}");
        }
    }
}

/// Replay one case by seed (use after a `forall` failure).
pub fn replay<F: FnOnce(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(0xF00D ^ seed.wrapping_mul(0x9E3779B9));
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall(50, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let caught = std::panic::catch_unwind(|| {
            forall(50, |rng| {
                // fails for some case eventually
                assert!(rng.f64() < 0.9, "drew a large value");
            });
        });
        let err = caught.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{err:?}"));
        assert!(msg.contains("case seed="), "{msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = None;
        replay(7, |rng| first = Some(rng.next_u64()));
        let mut second = None;
        replay(7, |rng| second = Some(rng.next_u64()));
        assert_eq!(first, second);
    }
}
