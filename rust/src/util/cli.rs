//! Tiny CLI argument parser (offline substitute for `clap`).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`; unknown keys are
//! collected so callers can reject them with a helpful message.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (unit-testable) — the first token
    /// that doesn't start with `--` becomes the subcommand.
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn parse_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects a number, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("repro table3 --seed 7 --scenario=global --full");
        assert_eq!(a.subcommand.as_deref(), Some("repro"));
        assert_eq!(a.positional, vec!["table3"]);
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("scenario"), Some("global"));
        assert!(a.flag("full"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn typed_getters_with_defaults() {
        let a = parse("train --rounds 25 --lr 0.05");
        assert_eq!(a.get_usize("rounds", 100), 25);
        assert_eq!(a.get_usize("clients", 100), 100);
        assert!((a.get_f64("lr", 0.1) - 0.05).abs() < 1e-12);
        assert_eq!(a.get_str("preset", "tiny"), "tiny");
    }

    #[test]
    fn trailing_flag_not_eaten_as_value() {
        let a = parse("x --verbose --n 5");
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("n", 0), 5);
    }
}
