//! Minimal JSON parser + writer (RFC 8259 subset, no external crates).
//!
//! Used for the AOT artifact manifests (`artifacts/*_manifest.json`) and
//! for machine-readable experiment reports. Supports the full value model
//! (null/bool/number/string/array/object) with UTF-8 strings and the
//! standard escapes; numbers are f64 (adequate: manifests carry shapes and
//! counts well below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["entry_points", "train_step", "inputs"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line rendering (no indentation) — the journal's framed
    /// payload format, where record size matters more than readability.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

/// Encode a u64 losslessly as a hex string. `Json::Num` is an f64, which
/// silently rounds integers above 2^53 — epoch tokens, step counters and
/// xoshiro state words in checkpoints use the full 64-bit range, so they
/// travel as strings.
pub fn u64_hex(x: u64) -> Json {
    Json::Str(format!("{x:#x}"))
}

/// Decode a [`u64_hex`] value (also accepts a plain integer `Num` for
/// hand-written documents, as long as it is exactly representable).
pub fn parse_u64_hex(j: &Json) -> Result<u64, String> {
    match j {
        Json::Str(s) => {
            let digits = s.strip_prefix("0x").unwrap_or(s);
            u64::from_str_radix(digits, 16)
                .map_err(|e| format!("bad hex u64 {s:?}: {e}"))
        }
        Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x < 9.007199254740992e15 => {
            Ok(*x as u64)
        }
        other => Err(format!("expected hex u64 string, got {other:?}")),
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (got {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        c => {
                            return Err(format!("bad escape \\{}", c as char))
                        }
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' got {:?} at {}",
                        other.map(|b| b as char),
                        self.i
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' got {:?} at {}",
                        other.map(|b| b as char),
                        self.i
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
          "preset": "tiny", "param_count": 2632,
          "entry_points": {"train_step": {"inputs": [["f32", [2632]], ["i32", [16]]]}}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("preset").unwrap().as_str(), Some("tiny"));
        assert_eq!(j.get("param_count").unwrap().as_usize(), Some(2632));
        let inputs = j
            .at(&["entry_points", "train_step", "inputs"])
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(inputs[0].as_arr().unwrap()[0].as_str(), Some("f32"));
        assert_eq!(
            inputs[0].as_arr().unwrap()[1].as_arr().unwrap()[0].as_usize(),
            Some(2632)
        );
    }

    #[test]
    fn roundtrip() {
        let doc = obj(vec![
            ("a", num(1.5)),
            ("b", arr(vec![Json::Null, Json::Bool(false), s("x\"y")])),
            ("c", obj(vec![("nested", num(3.0))])),
        ]);
        let text = doc.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{bad}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(num(42.0).to_string_pretty(), "42");
    }

    #[test]
    fn compact_rendering_is_single_line_and_reparses() {
        let doc = obj(vec![
            ("a", num(1.5)),
            ("b", arr(vec![Json::Null, s("x")])),
        ]);
        let text = doc.to_string_compact();
        assert!(!text.contains('\n'));
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn u64_hex_roundtrips_full_range() {
        for x in [0u64, 1, 42, u64::MAX, 0xDEAD_BEEF_CAFE_F00D, 1 << 53] {
            assert_eq!(parse_u64_hex(&u64_hex(x)).unwrap(), x);
        }
        // plain small integers are accepted for hand-written docs
        assert_eq!(parse_u64_hex(&num(7.0)).unwrap(), 7);
        assert!(parse_u64_hex(&num(1.5)).is_err());
        assert!(parse_u64_hex(&s("0xzz")).is_err());
        // f64 can't hold u64::MAX — proving why the string encoding exists
        assert_ne!(u64::MAX as f64 as u64, u64::MAX);
    }
}
