//! fedzero — leader entrypoint + CLI.
//!
//! Subcommands:
//!   train                  run one experiment (see --help)
//!   selftest               load artifacts, verify PJRT numerics
//!   repro <id>             regenerate a paper table/figure:
//!       fig1 fig2 fig4 table2 fig5 table3 fig6 table4 fig7 fig8
//!   campaign <spec.json>   declarative multi-scenario sweep
//!       (alias: repro campaign <spec.json>)
//!   help
//!
//! Every repro harness prints the same rows/series the paper reports, at a
//! reduced default scale (--full for paper scale; see EXPERIMENTS.md).

use anyhow::Result;
use fedzero::util::cli::Args;

mod repro;

fn main() -> Result<()> {
    let args = Args::parse_env();
    match args.subcommand.as_deref() {
        Some("train") => repro::cmd_train(&args),
        Some("selftest") => repro::cmd_selftest(&args),
        Some("repro") => repro::cmd_repro(&args),
        Some("campaign") => repro::cmd_campaign(&args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        "fedzero — FedZero paper reproduction (e-Energy '24)

USAGE:
    fedzero train   [--preset tiny|vision|imagenet|seq|speech]
                    [--scenario global|colocated] [--strategy <name>]
                    [--days N] [--clients N] [--n N] [--dmax N]
                    [--seed N] [--scale X] [--mock] [--out FILE]
                    [--checkpoint DIR [--snapshot-every N] [--resume]]
                    --checkpoint keeps a write-ahead journal + snapshots
                    in DIR; --resume continues a killed run from it,
                    bit-identical to a run that never crashed
    fedzero selftest [--preset tiny] [--artifacts DIR]
    fedzero repro   fig1|fig2|fig4|table2|fig5|table3|fig6|table4|fig7|fig8
                    [--full] [--mock] [--preset ...] [--seed N]
    fedzero campaign <spec.json>|smoke [--workers N] [--out FILE]
                    [--resume DIR]
                    declarative sweep grid (sites × α × errors × battery
                    × churn × strategy × seed); writes a deterministic
                    CAMPAIGN_report.json — see README for the schema.
                    --resume records finished cells under DIR and skips
                    them on rerun (same byte-identical report)

Strategies: FedZero, FedZero-exact, Random, Random-1.3n, Random-fc,
            Oort, Oort-1.3n, Oort-fc, Upper-bound.
Artifacts must exist (make artifacts) unless --mock is given;
campaigns always run the deterministic mock backend."
    );
}
