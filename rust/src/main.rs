//! fedzero — leader entrypoint + CLI.
//!
//! Subcommands:
//!   train                  run one experiment (see --help)
//!   selftest               load artifacts, verify PJRT numerics
//!   repro <id>             regenerate a paper table/figure:
//!       fig1 fig2 fig4 table2 fig5 table3 fig6 table4 fig7 fig8
//!   campaign <spec.json>   declarative multi-scenario sweep
//!       (alias: repro campaign <spec.json>)
//!   help
//!
//! Every repro harness prints the same rows/series the paper reports, at a
//! reduced default scale (--full for paper scale; see EXPERIMENTS.md).
//!
//! Global flags (all subcommands):
//!   --verbose / --quiet    log level (also FEDZERO_LOG=error|info|debug)
//!   --trace FILE           arm span tracing, write a Chrome trace-event
//!                          file on exit (chrome://tracing / Perfetto)
//!   --telemetry [FILE]     collect counters/histograms, write a
//!                          TELEMETRY.json summary on exit
//!                          (also FEDZERO_TELEMETRY=1 or =FILE)

use anyhow::Result;
use fedzero::util::cli::Args;
use fedzero::util::obs;

mod repro;

/// Resolve the observability flags before any work runs. Returns the
/// (telemetry, trace) output paths to write after the subcommand.
fn init_obs(args: &Args) -> (Option<String>, Option<String>) {
    if args.flag("verbose") {
        obs::set_level(obs::Level::Debug);
    } else if args.flag("quiet") {
        obs::set_level(obs::Level::Error);
    }
    let trace_path = args.get("trace").map(|s| s.to_string());
    let telemetry_path = args
        .get("telemetry")
        .map(|s| s.to_string())
        .or_else(|| {
            if args.flag("telemetry") {
                Some("TELEMETRY.json".to_string())
            } else {
                None
            }
        })
        .or_else(|| match std::env::var("FEDZERO_TELEMETRY").ok()?.as_str() {
            "" | "0" => None,
            "1" | "true" => Some("TELEMETRY.json".to_string()),
            path => Some(path.to_string()),
        });
    if telemetry_path.is_some() {
        obs::set_enabled(true);
    }
    if trace_path.is_some() {
        obs::set_tracing(true);
    }
    (telemetry_path, trace_path)
}

fn write_obs(telemetry: &Option<String>, trace: &Option<String>) -> Result<()> {
    if let Some(p) = telemetry {
        obs::write_telemetry(std::path::Path::new(p))?;
        obs::log!(info, "wrote {p}");
    }
    if let Some(p) = trace {
        obs::write_trace(std::path::Path::new(p))?;
        obs::log!(info, "wrote {p}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse_env();
    let (telemetry, trace) = init_obs(&args);
    let result = match args.subcommand.as_deref() {
        Some("train") => repro::cmd_train(&args),
        Some("selftest") => repro::cmd_selftest(&args),
        Some("repro") => repro::cmd_repro(&args),
        Some("campaign") => repro::cmd_campaign(&args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            obs::log!(error, "unknown subcommand {other:?}\n");
            print_help();
            std::process::exit(2);
        }
    };
    // exports run even when the subcommand failed: a crashed run's
    // partial telemetry is exactly what you want to look at
    write_obs(&telemetry, &trace)?;
    result
}

fn print_help() {
    obs::log!(
        info,
        "fedzero — FedZero paper reproduction (e-Energy '24)

USAGE:
    fedzero train   [--preset tiny|vision|imagenet|seq|speech]
                    [--scenario global|colocated] [--strategy <name>]
                    [--days N] [--clients N] [--n N] [--dmax N]
                    [--seed N] [--scale X] [--mock] [--out FILE]
                    [--checkpoint DIR [--snapshot-every N] [--resume]]
                    --checkpoint keeps a write-ahead journal + snapshots
                    in DIR; --resume continues a killed run from it,
                    bit-identical to a run that never crashed
    fedzero selftest [--preset tiny] [--artifacts DIR]
    fedzero repro   fig1|fig2|fig4|table2|fig5|table3|fig6|table4|fig7|fig8
                    [--full] [--mock] [--preset ...] [--seed N]
    fedzero campaign <spec.json>|smoke [--workers N] [--out FILE]
                    [--resume DIR]
                    declarative sweep grid (sites × α × errors × battery
                    × churn × strategy × seed); writes a deterministic
                    CAMPAIGN_report.json — see README for the schema.
                    --resume records finished cells under DIR and skips
                    them on rerun (same byte-identical report)

Observability (any subcommand):
    --verbose | --quiet     log level (or FEDZERO_LOG=error|info|debug)
    --trace FILE            Chrome trace-event span timeline
    --telemetry [FILE]      counters + latency histograms, default
                            TELEMETRY.json (or FEDZERO_TELEMETRY=1)
    Telemetry never changes deterministic outputs: metrics, model bits,
    journal bytes and campaign reports are bit-identical on or off.

Strategies: FedZero, FedZero-exact, Random, Random-1.3n, Random-fc,
            Oort, Oort-1.3n, Oort-fc, Upper-bound.
Artifacts must exist (make artifacts) unless --mock is given;
campaigns always run the deterministic mock backend."
    );
}
