//! PJRT-backed training backend: the production path that executes the
//! AOT-compiled HLO artifacts on real (synthetic-task) data.
//!
//! Fits the shard/`Sync` split (module docs) as a read-mostly core: the
//! runtime, dataset and hyper-parameters are immutable after
//! construction, and the per-client epoch cursor lives in the
//! caller-owned [`ClientTrainState`] as [`XlaCursor`]. `train_shard`
//! keeps the serial default for now: the PJRT wrapper types are not
//! known to be `Sync` (the underlying client is reference-counted in the
//! bindings), so fanning `&self` across threads is not provably sound —
//! the simulator still gets bit-identical results either way, and the
//! mock backend exercises the parallel path.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::{BatchStats, ClientTrainState, TrainBackend};
use crate::data::{Partition, SynthDataset};
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

/// Per-client epoch cursor: a shuffled index permutation over the
/// client's shard, re-shuffled at each epoch boundary so local training
/// visits data the way a real FL client does. The sample ids themselves
/// are shared with the backend (`Arc`), so the only per-cursor storage
/// is the u32 permutation — at 100k-client scale the shard ids are not
/// duplicated. Shuffling the permutation consumes the same RNG draws and
/// yields the same id sequence as shuffling the ids directly. Owned by
/// the caller via [`ClientTrainState`]; `Send` so shards can move across
/// workers.
pub struct XlaCursor {
    ids: Arc<[usize]>,
    order: Vec<u32>,
    pos: usize,
    rng: Rng,
}

impl XlaCursor {
    fn new(ids: Arc<[usize]>, seed: u64) -> XlaCursor {
        let mut rng = Rng::new(seed);
        let mut order: Vec<u32> = (0..ids.len() as u32).collect();
        rng.shuffle(&mut order);
        XlaCursor { ids, order, pos: 0, rng }
    }

    fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(batch);
        for _ in 0..batch {
            if self.pos >= self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.pos = 0;
            }
            out.push(self.ids[self.order[self.pos] as usize]);
            self.pos += 1;
        }
        out
    }
}

pub struct XlaBackend {
    pub runtime: ModelRuntime,
    pub dataset: SynthDataset,
    /// per-client sample-id shards, shared with the cursors
    shards: Vec<Arc<[usize]>>,
    seed: u64,
    pub lr: f32,
    pub mu: f32,
    /// cap on eval set size (speeds up frequent evals; 0 = all)
    pub eval_subset: usize,
}

impl XlaBackend {
    pub fn new(
        runtime: ModelRuntime,
        dataset: SynthDataset,
        partition: &Partition,
        lr: f32,
        mu: f32,
        seed: u64,
    ) -> Result<XlaBackend> {
        if dataset.dim != runtime.manifest.input_dim {
            return Err(anyhow!(
                "dataset dim {} != model input dim {}",
                dataset.dim,
                runtime.manifest.input_dim
            ));
        }
        Ok(XlaBackend {
            runtime,
            dataset,
            shards: partition
                .clients
                .iter()
                .map(|samples| Arc::from(samples.as_slice()))
                .collect(),
            seed,
            lr,
            mu,
            eval_subset: 0,
        })
    }

    fn gather_batch(&self, ids: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let d = self.dataset.dim;
        let mut x = Vec::with_capacity(ids.len() * d);
        let mut y = Vec::with_capacity(ids.len());
        for &i in ids {
            x.extend_from_slice(self.dataset.train_row(i));
            y.push(self.dataset.train_y[i]);
        }
        (x, y)
    }
}

impl TrainBackend for XlaBackend {
    type Cursor = XlaCursor;

    fn param_count(&self) -> usize {
        self.runtime.param_count()
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        self.runtime.init_params(seed)
    }

    fn make_cursor(&self, client: usize) -> XlaCursor {
        XlaCursor::new(
            self.shards[client].clone(),
            self.seed ^ (client as u64) << 17,
        )
    }

    fn train_batches(
        &self,
        client: usize,
        state: &mut ClientTrainState<XlaCursor>,
        global: &[f32],
        n_batches: usize,
    ) -> Result<BatchStats> {
        let b = self.runtime.batch_size();
        let mut loss_sum = 0.0f64;
        let mut correct = 0i64;
        for _ in 0..n_batches {
            let ids = state.cursor.next_batch(b);
            let (x, y) = self.gather_batch(&ids);
            let out = self.runtime.train_step(
                &state.params,
                global,
                &x,
                &y,
                self.lr,
                self.mu,
            )?;
            state.params = out.params;
            loss_sum += out.loss as f64;
            correct += out.correct as i64;
        }
        Ok(BatchStats {
            batches: n_batches,
            mean_loss: if n_batches > 0 {
                loss_sum / n_batches as f64
            } else {
                0.0
            },
            accuracy: if n_batches > 0 {
                correct as f64 / (n_batches * b) as f64
            } else {
                0.0
            },
        })
    }

    fn aggregate(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        let k = self.runtime.manifest.agg_k;
        if updates.len() <= k {
            return self.runtime.aggregate(updates, weights);
        }
        // chunked aggregation for > K participants: combine partial
        // weighted means with their weight masses (one pre-sized
        // `chunk_masses` pass — the same helper the hierarchical tree
        // composition uses, so partial-mass math cannot drift). The
        // composition recurses so > K² participants reduce in as many
        // levels as needed instead of overflowing the runtime's K cap.
        if k < 2 {
            return Err(anyhow!(
                "agg_k={k} cannot compose {} updates",
                updates.len()
            ));
        }
        let mut masses: Vec<f32> = Vec::new();
        super::tree::chunk_masses(weights, k, &mut masses);
        let mut partials: Vec<Vec<f32>> = Vec::with_capacity(masses.len());
        for (chunk_u, chunk_w) in updates.chunks(k).zip(weights.chunks(k)) {
            partials.push(self.runtime.aggregate(chunk_u, chunk_w)?);
        }
        let refs: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
        self.aggregate(&refs, &masses)
    }

    fn evaluate(&self, params: &[f32]) -> Result<(f64, f64)> {
        let n = if self.eval_subset > 0 {
            self.eval_subset.min(self.dataset.test_len())
        } else {
            self.dataset.test_len()
        };
        let d = self.dataset.dim;
        self.runtime.evaluate_dataset(
            params,
            &self.dataset.test_x[..n * d],
            &self.dataset.test_y[..n],
        )
    }
}
