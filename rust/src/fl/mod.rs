//! Federated-learning server substrate: the training backend abstraction
//! (PJRT-backed in production, deterministic mock for simulator tests),
//! per-client local training state, and FedAvg aggregation plumbing.
//!
//! §Design — the shard/`Sync` split. [`TrainBackend`] is a *read-mostly
//! core*: model layout, datasets, per-client optima, hyper-parameters —
//! everything shared across clients — accessed through `&self` only. All
//! per-client mutable state (local params, data cursor, step counter)
//! lives in a caller-owned [`ClientTrainState`], handed back to
//! [`TrainBackend::train_batches`] by `&mut`. Because the core is never
//! mutably borrowed by training, a `Sync` backend can train whole power
//! domains concurrently: the simulator fans a step's train jobs out over
//! `util::par::steal` workers via [`TrainBackend::train_shard`], each
//! [`TrainJob`] claimed by exactly one worker (batch counts differ
//! wildly per client, so idle workers steal queued jobs instead of
//! waiting behind a monster one).
//!
//! §Determinism invariant — the shard fan-out must be unobservable:
//! `train_batches` may depend only on `(client, state, global, n)`, and
//! each job owns its client's state exclusively, so any schedule of jobs
//! across workers produces bit-identical params and [`BatchStats`] per
//! job. The simulator keeps everything order-sensitive — energy metering,
//! progress, loss accounting, aggregation — *serial* in the historical
//! (domain, slot) order, so parallel and serial training yield
//! bit-identical `MetricsLog`s and global models (enforced by engine
//! tests and the endtoend bench gate).
//!
//! §Step accounting — there is no shared step counter (the historical
//! `steps_executed() -> 0` trait default silently under-reported for
//! backends that forgot to override it, and a shared `&mut`/`Cell`
//! counter cannot cross the fan-out). Instead the shard layer bumps
//! `ClientTrainState::steps` once per job, and totals are a
//! deterministic reduction over the per-client counters in client-index
//! order (`Simulation::steps_executed`).

pub mod backend;
pub mod mock;
pub mod tree;

pub use backend::{XlaBackend, XlaCursor};
pub use mock::MockBackend;
pub use tree::{AggMode, TreeAggregator};

use anyhow::Result;

use crate::util::par;

/// Stats reported by a client after a chunk of local batches.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    pub batches: usize,
    pub mean_loss: f64,
    pub accuracy: f64,
}

/// Per-client mutable training state, owned by the caller (the simulator
/// keeps one per client for the whole run) so the backend core stays
/// `&self` during training. `C` is the backend's cursor type — the
/// epoch-shuffle position for the PJRT backend, `()` for the mock.
pub struct ClientTrainState<C> {
    /// local model params; reset from the global snapshot at round start
    /// (in place, reusing capacity) and read back for aggregation
    pub params: Vec<f32>,
    /// backend-specific data cursor (persists across rounds so local
    /// training continues the client's epoch where it left off)
    pub cursor: C,
    /// train-step executions recorded through this state — bumped by the
    /// shard layer, summed per client in index order for perf accounting
    pub steps: u64,
}

impl<C> ClientTrainState<C> {
    pub fn new(cursor: C) -> Self {
        ClientTrainState { params: Vec::new(), cursor, steps: 0 }
    }

    /// Reset the local params to the global snapshot, reusing capacity.
    pub fn reset_params(&mut self, global: &[f32]) {
        self.params.clear();
        self.params.extend_from_slice(global);
    }
}

/// One unit of shard training (plain data, no borrows): run `n_batches`
/// local minibatches for `client` against the state at index `slot` of
/// the arena passed alongside the shard. Jobs in a shard reference
/// *distinct* slots in strictly increasing order, so they are
/// independent by construction AND the state arena can be split into
/// disjoint per-worker blocks without unsafe code.
///
/// §Perf (ROADMAP "per-step job vec"): because a job carries an index
/// instead of an `&mut` borrow, the simulator hoists ONE `Vec<TrainJob>`
/// to round scope and refills it in place every step — training steps
/// are allocation-free again.
#[derive(Clone, Copy, Debug)]
pub struct TrainJob {
    pub client: usize,
    pub n_batches: usize,
    /// index into the `states` arena handed to [`TrainBackend::train_shard`]
    pub slot: usize,
    /// filled by [`TrainBackend::train_shard`] on success
    pub stats: BatchStats,
}

impl TrainJob {
    pub fn new(client: usize, n_batches: usize, slot: usize) -> Self {
        TrainJob { client, n_batches, slot, stats: BatchStats::default() }
    }
}

/// The compute interface the simulator drives. Implementations are a
/// read-mostly core (see the module docs); per-client mutation goes
/// through the caller-owned [`ClientTrainState`].
pub trait TrainBackend {
    /// Backend-specific per-client cursor carried in [`ClientTrainState`].
    type Cursor: Send;

    fn param_count(&self) -> usize;

    /// fresh global model
    fn init_params(&self, seed: i32) -> Result<Vec<f32>>;

    /// Fresh cursor for `client` (called once per client at sim start;
    /// deterministic given the backend's seed).
    fn make_cursor(&self, client: usize) -> Self::Cursor;

    /// Run `n_batches` local minibatches for `client`, updating
    /// `state.params` in place (FedProx against `global`) and advancing
    /// `state.cursor`. Must depend only on `(client, state, global,
    /// n_batches)` — the determinism invariant the shard fan-out relies
    /// on. Does NOT touch `state.steps`; the shard layer owns step
    /// accounting.
    fn train_batches(
        &self,
        client: usize,
        state: &mut ClientTrainState<Self::Cursor>,
        global: &[f32],
        n_batches: usize,
    ) -> Result<BatchStats>;

    /// Run a shard of independent train jobs (distinct `slot`s, strictly
    /// increasing) against the `states` arena, filling `job.stats` and
    /// bumping each slot state's step counter. The default runs jobs
    /// serially in slice order and stops at the first error; `Sync`
    /// backends override it with [`train_shard_parallel`], which is
    /// bit-identical on success and reports the same (smallest-index)
    /// error on failure. State beyond a failing job is unspecified —
    /// callers abort the run on error.
    fn train_shard(
        &self,
        global: &[f32],
        jobs: &mut [TrainJob],
        states: &mut [ClientTrainState<Self::Cursor>],
    ) -> Result<()> {
        for j in jobs.iter_mut() {
            let st = &mut states[j.slot];
            j.stats = self.train_batches(j.client, st, global, j.n_batches)?;
            st.steps += j.n_batches as u64;
        }
        Ok(())
    }

    /// FedAvg over client models with the given weights (rows borrowed
    /// straight from the clients' [`ClientTrainState::params`]).
    fn aggregate(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>>;

    /// centralized test-set evaluation -> (accuracy, mean loss)
    fn evaluate(&self, params: &[f32]) -> Result<(f64, f64)>;

    /// Serialise one client's data cursor for a checkpoint. `None`
    /// means the backend does not support cursor checkpointing — a
    /// durable run then fails loudly at its first snapshot instead of
    /// resuming with silently rewound data order. The mock backend's
    /// `()` cursor trivially supports it; the PJRT epoch-shuffle cursor
    /// is carried-forward work.
    fn cursor_to_json(&self, _cursor: &Self::Cursor) -> Option<crate::util::json::Json> {
        None
    }

    /// Rebuild a cursor from [`TrainBackend::cursor_to_json`] output.
    fn cursor_from_json(
        &self,
        _client: usize,
        _state: &crate::util::json::Json,
    ) -> Result<Self::Cursor> {
        Err(anyhow::anyhow!(
            "this backend does not support cursor checkpointing"
        ))
    }
}

/// Work-stealing shard training for `Sync` backends
/// ([`train_shard_stealing`] with the auto worker count): bit-identical
/// to the serial default of [`TrainBackend::train_shard`] on success,
/// same (smallest-job-index) error on failure.
pub fn train_shard_parallel<B>(
    backend: &B,
    global: &[f32],
    jobs: &mut [TrainJob],
    states: &mut [ClientTrainState<B::Cursor>],
    min_par: usize,
) -> Result<()>
where
    B: TrainBackend + Sync + ?Sized,
    B::Cursor: Send,
{
    train_shard_stealing(backend, global, jobs, states, min_par, 0)
}

/// Shard training over `util::par::steal` for `Sync` backends: workers
/// (`0` = auto) claim job indices dynamically once the shard has at
/// least `min_par` jobs, so one monster job (`TrainJob::n_batches` is
/// wildly uneven across clients) no longer pins a whole contiguous
/// block behind it — the historical uniform split left every other
/// worker idle at the join.
///
/// Job `j` touches exactly `jobs[j]` and `states[jobs[j].slot]`; slots
/// are strictly increasing across a shard, so both are exclusive to
/// whichever worker claims index `j` and the result is bit-identical to
/// the serial loop at any worker count. On failure the stealing path
/// still runs the remaining jobs (a thief may already be past the
/// failing index) and reports the error with the *smallest job index*
/// after the join — the same error the serial short-circuit reports.
/// State beyond a failing job is unspecified either way; callers abort
/// the run on error.
pub fn train_shard_stealing<B>(
    backend: &B,
    global: &[f32],
    jobs: &mut [TrainJob],
    states: &mut [ClientTrainState<B::Cursor>],
    min_par: usize,
    workers: usize,
) -> Result<()>
where
    B: TrainBackend + Sync + ?Sized,
    B::Cursor: Send,
{
    debug_assert!(
        jobs.windows(2).all(|w| w[0].slot < w[1].slot),
        "train_shard jobs must reference strictly increasing slots"
    );
    debug_assert!(jobs.last().map_or(true, |j| j.slot < states.len()));

    let n_jobs = jobs.len();
    if n_jobs < min_par.max(1) || par::steal::resolve_workers(workers) <= 1 {
        // identical to the serial default (first error short-circuits —
        // in index order, so it IS the smallest-index error)
        for j in jobs.iter_mut() {
            let st = &mut states[j.slot];
            j.stats = backend.train_batches(j.client, st, global, j.n_batches)?;
            st.steps += j.n_batches as u64;
        }
        return Ok(());
    }
    let jobs_shared = par::steal::SharedUnits::new(jobs, 1);
    let states_shared = par::steal::SharedUnits::new(states, 1);
    let (jobs_shared, states_shared) = (&jobs_shared, &states_shared);
    let (locals, _stats) = par::steal::steal_exec(
        n_jobs,
        workers,
        |_| None::<(usize, anyhow::Error)>,
        |ji, first_err| {
            // SAFETY: the scheduler hands job index `ji` to exactly one
            // worker, and distinct jobs carry distinct slots (strictly
            // increasing, asserted above), so both views are exclusive.
            let job = unsafe { &mut jobs_shared.unit(ji)[0] };
            let st = unsafe { &mut states_shared.unit(job.slot)[0] };
            match backend.train_batches(job.client, st, global, job.n_batches) {
                Ok(stats) => {
                    job.stats = stats;
                    st.steps += job.n_batches as u64;
                }
                Err(e) => {
                    if first_err.as_ref().map_or(true, |(fj, _)| ji < *fj) {
                        *first_err = Some((ji, e));
                    }
                }
            }
        },
    );
    // canonical error reduction: every job ran exactly once, so the
    // smallest failing index was observed by whichever worker ran it
    let mut first: Option<(usize, anyhow::Error)> = None;
    for local in locals.into_iter().flatten() {
        if first.as_ref().map_or(true, |(fj, _)| local.0 < *fj) {
            first = Some(local);
        }
    }
    match first {
        Some((_, e)) => Err(e),
        None => Ok(()),
    }
}

/// FedAvg weights from sample counts (the standard weighting the paper's
/// Flower setup uses).
pub fn fedavg_weights(sample_counts: &[usize]) -> Vec<f32> {
    sample_counts.iter().map(|&s| s as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fedavg_weights_are_sample_counts() {
        assert_eq!(fedavg_weights(&[10, 0, 5]), vec![10.0, 0.0, 5.0]);
    }

    #[test]
    fn default_train_shard_fills_stats_and_steps() {
        let b = MockBackend::new(3, 4, 0.1, 9);
        let global = b.init_params(0).unwrap();
        let mut states: Vec<ClientTrainState<()>> = (0..3)
            .map(|c| {
                let mut st = ClientTrainState::new(b.make_cursor(c));
                st.reset_params(&global);
                st
            })
            .collect();
        let mut jobs: Vec<TrainJob> =
            (0..3).map(|c| TrainJob::new(c, 2 + c, c)).collect();
        b.train_shard(&global, &mut jobs, &mut states).unwrap();
        for (c, j) in jobs.iter().enumerate() {
            assert_eq!(j.stats.batches, 2 + c);
            assert!(j.stats.mean_loss > 0.0);
        }
        let steps: Vec<u64> = states.iter().map(|s| s.steps).collect();
        assert_eq!(steps, vec![2, 3, 4]);
    }

    #[test]
    fn sparse_slot_shard_splits_states_correctly() {
        // jobs over a strict subset of non-contiguous slots, forced
        // through the parallel splitter: only the referenced slots train,
        // and the split arithmetic must hold for every block boundary
        let n = 9usize;
        let b = MockBackend::new(n, 6, 0.1, 4);
        let global = b.init_params(1).unwrap();
        let mut states: Vec<ClientTrainState<()>> = (0..n)
            .map(|c| {
                let mut st = ClientTrainState::new(b.make_cursor(c));
                st.reset_params(&global);
                st
            })
            .collect();
        let slots = [0usize, 2, 3, 6, 8];
        let mut jobs: Vec<TrainJob> =
            slots.iter().map(|&s| TrainJob::new(s, 1 + s % 3, s)).collect();
        train_shard_parallel(&b, &global, &mut jobs, &mut states, 1).unwrap();
        for s in 0..n {
            let expect = if slots.contains(&s) { (1 + s % 3) as u64 } else { 0 };
            assert_eq!(states[s].steps, expect, "slot {s}");
        }
        for j in &jobs {
            assert_eq!(j.stats.batches, 1 + j.slot % 3);
        }
    }
}
