//! Federated-learning server substrate: the training backend abstraction
//! (PJRT-backed in production, deterministic mock for simulator tests),
//! local client training state, and FedAvg aggregation plumbing.

pub mod backend;
pub mod mock;

pub use backend::XlaBackend;
pub use mock::MockBackend;

use anyhow::Result;

/// Stats reported by a client after a chunk of local batches.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    pub batches: usize,
    pub mean_loss: f64,
    pub accuracy: f64,
}

/// The compute interface the simulator drives. Implementations own the
/// model state layout (flat f32 vector) and the local datasets.
pub trait TrainBackend {
    fn param_count(&self) -> usize;

    /// fresh global model
    fn init_params(&mut self, seed: i32) -> Result<Vec<f32>>;

    /// Run `n_batches` local minibatches for `client`, updating `params`
    /// in place (FedProx against `global`). Implementations keep the
    /// per-client data cursor so consecutive calls continue the epoch.
    fn train_batches(
        &mut self,
        client: usize,
        params: &mut Vec<f32>,
        global: &[f32],
        n_batches: usize,
    ) -> Result<BatchStats>;

    /// FedAvg over client models with the given weights.
    fn aggregate(&mut self, updates: &[Vec<f32>], weights: &[f32]) -> Result<Vec<f32>>;

    /// centralized test-set evaluation -> (accuracy, mean loss)
    fn evaluate(&mut self, params: &[f32]) -> Result<(f64, f64)>;

    /// total train-step executions so far (perf accounting)
    fn steps_executed(&self) -> u64 {
        0
    }
}

/// FedAvg weights from sample counts (the standard weighting the paper's
/// Flower setup uses).
pub fn fedavg_weights(sample_counts: &[usize]) -> Vec<f32> {
    sample_counts.iter().map(|&s| s as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fedavg_weights_are_sample_counts() {
        assert_eq!(fedavg_weights(&[10, 0, 5]), vec![10.0, 0.0, 5.0]);
    }
}
