//! Federated-learning server substrate: the training backend abstraction
//! (PJRT-backed in production, deterministic mock for simulator tests),
//! per-client local training state, and FedAvg aggregation plumbing.
//!
//! §Design — the shard/`Sync` split. [`TrainBackend`] is a *read-mostly
//! core*: model layout, datasets, per-client optima, hyper-parameters —
//! everything shared across clients — accessed through `&self` only. All
//! per-client mutable state (local params, data cursor, step counter)
//! lives in a caller-owned [`ClientTrainState`], handed back to
//! [`TrainBackend::train_batches`] by `&mut`. Because the core is never
//! mutably borrowed by training, a `Sync` backend can train whole power
//! domains concurrently: the simulator fans a step's train jobs out over
//! `util::par` workers via [`TrainBackend::train_shard`], each worker
//! driving a disjoint block of [`TrainJob`]s.
//!
//! §Determinism invariant — the shard fan-out must be unobservable:
//! `train_batches` may depend only on `(client, state, global, n)`, and
//! each job owns its client's state exclusively, so any schedule of jobs
//! across workers produces bit-identical params and [`BatchStats`] per
//! job. The simulator keeps everything order-sensitive — energy metering,
//! progress, loss accounting, aggregation — *serial* in the historical
//! (domain, slot) order, so parallel and serial training yield
//! bit-identical `MetricsLog`s and global models (enforced by engine
//! tests and the endtoend bench gate).
//!
//! §Step accounting — there is no shared step counter (the historical
//! `steps_executed() -> 0` trait default silently under-reported for
//! backends that forgot to override it, and a shared `&mut`/`Cell`
//! counter cannot cross the fan-out). Instead the shard layer bumps
//! `ClientTrainState::steps` once per job, and totals are a
//! deterministic reduction over the per-client counters in client-index
//! order (`Simulation::steps_executed`).

pub mod backend;
pub mod mock;
pub mod tree;

pub use backend::{XlaBackend, XlaCursor};
pub use mock::MockBackend;
pub use tree::{AggMode, TreeAggregator};

use anyhow::Result;

use crate::util::par;

/// Stats reported by a client after a chunk of local batches.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    pub batches: usize,
    pub mean_loss: f64,
    pub accuracy: f64,
}

/// Per-client mutable training state, owned by the caller (the simulator
/// keeps one per client for the whole run) so the backend core stays
/// `&self` during training. `C` is the backend's cursor type — the
/// epoch-shuffle position for the PJRT backend, `()` for the mock.
pub struct ClientTrainState<C> {
    /// local model params; reset from the global snapshot at round start
    /// (in place, reusing capacity) and read back for aggregation
    pub params: Vec<f32>,
    /// backend-specific data cursor (persists across rounds so local
    /// training continues the client's epoch where it left off)
    pub cursor: C,
    /// train-step executions recorded through this state — bumped by the
    /// shard layer, summed per client in index order for perf accounting
    pub steps: u64,
}

impl<C> ClientTrainState<C> {
    pub fn new(cursor: C) -> Self {
        ClientTrainState { params: Vec::new(), cursor, steps: 0 }
    }

    /// Reset the local params to the global snapshot, reusing capacity.
    pub fn reset_params(&mut self, global: &[f32]) {
        self.params.clear();
        self.params.extend_from_slice(global);
    }
}

/// One unit of shard training (plain data, no borrows): run `n_batches`
/// local minibatches for `client` against the state at index `slot` of
/// the arena passed alongside the shard. Jobs in a shard reference
/// *distinct* slots in strictly increasing order, so they are
/// independent by construction AND the state arena can be split into
/// disjoint per-worker blocks without unsafe code.
///
/// §Perf (ROADMAP "per-step job vec"): because a job carries an index
/// instead of an `&mut` borrow, the simulator hoists ONE `Vec<TrainJob>`
/// to round scope and refills it in place every step — training steps
/// are allocation-free again.
#[derive(Clone, Copy, Debug)]
pub struct TrainJob {
    pub client: usize,
    pub n_batches: usize,
    /// index into the `states` arena handed to [`TrainBackend::train_shard`]
    pub slot: usize,
    /// filled by [`TrainBackend::train_shard`] on success
    pub stats: BatchStats,
}

impl TrainJob {
    pub fn new(client: usize, n_batches: usize, slot: usize) -> Self {
        TrainJob { client, n_batches, slot, stats: BatchStats::default() }
    }
}

/// The compute interface the simulator drives. Implementations are a
/// read-mostly core (see the module docs); per-client mutation goes
/// through the caller-owned [`ClientTrainState`].
pub trait TrainBackend {
    /// Backend-specific per-client cursor carried in [`ClientTrainState`].
    type Cursor: Send;

    fn param_count(&self) -> usize;

    /// fresh global model
    fn init_params(&self, seed: i32) -> Result<Vec<f32>>;

    /// Fresh cursor for `client` (called once per client at sim start;
    /// deterministic given the backend's seed).
    fn make_cursor(&self, client: usize) -> Self::Cursor;

    /// Run `n_batches` local minibatches for `client`, updating
    /// `state.params` in place (FedProx against `global`) and advancing
    /// `state.cursor`. Must depend only on `(client, state, global,
    /// n_batches)` — the determinism invariant the shard fan-out relies
    /// on. Does NOT touch `state.steps`; the shard layer owns step
    /// accounting.
    fn train_batches(
        &self,
        client: usize,
        state: &mut ClientTrainState<Self::Cursor>,
        global: &[f32],
        n_batches: usize,
    ) -> Result<BatchStats>;

    /// Run a shard of independent train jobs (distinct `slot`s, strictly
    /// increasing) against the `states` arena, filling `job.stats` and
    /// bumping each slot state's step counter. The default runs jobs
    /// serially in slice order and stops at the first error; `Sync`
    /// backends override it with [`train_shard_parallel`], which is
    /// bit-identical on success and reports the same (smallest-index)
    /// error on failure. State beyond a failing job is unspecified —
    /// callers abort the run on error.
    fn train_shard(
        &self,
        global: &[f32],
        jobs: &mut [TrainJob],
        states: &mut [ClientTrainState<Self::Cursor>],
    ) -> Result<()> {
        for j in jobs.iter_mut() {
            let st = &mut states[j.slot];
            j.stats = self.train_batches(j.client, st, global, j.n_batches)?;
            st.steps += j.n_batches as u64;
        }
        Ok(())
    }

    /// FedAvg over client models with the given weights (rows borrowed
    /// straight from the clients' [`ClientTrainState::params`]).
    fn aggregate(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>>;

    /// centralized test-set evaluation -> (accuracy, mean loss)
    fn evaluate(&self, params: &[f32]) -> Result<(f64, f64)>;
}

/// Fork-join shard training for `Sync` backends: fans contiguous job
/// blocks out across `util::par` workers once the shard has at least
/// `min_par` jobs. Jobs carry strictly increasing `slot` indices, so the
/// state arena is split at block boundaries into disjoint `&mut` chunks
/// — each job still exclusively owns its client's state and the result
/// is bit-identical to the serial default of
/// [`TrainBackend::train_shard`]; on failure the error with the smallest
/// job index is reported regardless of chunking (blocks are joined in
/// ascending job order and each block stops at its first error).
pub fn train_shard_parallel<B>(
    backend: &B,
    global: &[f32],
    jobs: &mut [TrainJob],
    states: &mut [ClientTrainState<B::Cursor>],
    min_par: usize,
) -> Result<()>
where
    B: TrainBackend + Sync + ?Sized,
    B::Cursor: Send,
{
    debug_assert!(
        jobs.windows(2).all(|w| w[0].slot < w[1].slot),
        "train_shard jobs must reference strictly increasing slots"
    );
    debug_assert!(jobs.last().map_or(true, |j| j.slot < states.len()));

    fn run_block<B>(
        backend: &B,
        global: &[f32],
        jobs: &mut [TrainJob],
        states: &mut [ClientTrainState<B::Cursor>],
        base: usize,
    ) -> Result<()>
    where
        B: TrainBackend + ?Sized,
    {
        for j in jobs.iter_mut() {
            let st = &mut states[j.slot - base];
            j.stats = backend.train_batches(j.client, st, global, j.n_batches)?;
            st.steps += j.n_batches as u64;
        }
        Ok(())
    }

    let n_jobs = jobs.len();
    let workers = par::threads();
    if n_jobs < min_par.max(1) || workers <= 1 {
        return run_block(backend, global, jobs, states, 0);
    }
    let per = (n_jobs + workers - 1) / workers;
    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        let mut jobs_rest: &mut [TrainJob] = jobs;
        let mut states_rest: &mut [ClientTrainState<B::Cursor>] = states;
        let mut base = 0usize;
        let mut j0 = 0usize;
        while j0 < n_jobs {
            let take = per.min(n_jobs - j0);
            let tmp = std::mem::take(&mut jobs_rest);
            let (jb, jr) = tmp.split_at_mut(take);
            jobs_rest = jr;
            // every slot below the NEXT block's first slot belongs to
            // this block (slots strictly increase)
            let split = match jobs_rest.first() {
                Some(next) => next.slot - base,
                None => states_rest.len(),
            };
            let tmp_s = std::mem::take(&mut states_rest);
            let (sb, sr) = tmp_s.split_at_mut(split);
            states_rest = sr;
            let this_base = base;
            base += split;
            handles.push(s.spawn(move || run_block(backend, global, jb, sb, this_base)));
            j0 += take;
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("train shard worker panicked"))
            .collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

/// FedAvg weights from sample counts (the standard weighting the paper's
/// Flower setup uses).
pub fn fedavg_weights(sample_counts: &[usize]) -> Vec<f32> {
    sample_counts.iter().map(|&s| s as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fedavg_weights_are_sample_counts() {
        assert_eq!(fedavg_weights(&[10, 0, 5]), vec![10.0, 0.0, 5.0]);
    }

    #[test]
    fn default_train_shard_fills_stats_and_steps() {
        let b = MockBackend::new(3, 4, 0.1, 9);
        let global = b.init_params(0).unwrap();
        let mut states: Vec<ClientTrainState<()>> = (0..3)
            .map(|c| {
                let mut st = ClientTrainState::new(b.make_cursor(c));
                st.reset_params(&global);
                st
            })
            .collect();
        let mut jobs: Vec<TrainJob> =
            (0..3).map(|c| TrainJob::new(c, 2 + c, c)).collect();
        b.train_shard(&global, &mut jobs, &mut states).unwrap();
        for (c, j) in jobs.iter().enumerate() {
            assert_eq!(j.stats.batches, 2 + c);
            assert!(j.stats.mean_loss > 0.0);
        }
        let steps: Vec<u64> = states.iter().map(|s| s.steps).collect();
        assert_eq!(steps, vec![2, 3, 4]);
    }

    #[test]
    fn sparse_slot_shard_splits_states_correctly() {
        // jobs over a strict subset of non-contiguous slots, forced
        // through the parallel splitter: only the referenced slots train,
        // and the split arithmetic must hold for every block boundary
        let n = 9usize;
        let b = MockBackend::new(n, 6, 0.1, 4);
        let global = b.init_params(1).unwrap();
        let mut states: Vec<ClientTrainState<()>> = (0..n)
            .map(|c| {
                let mut st = ClientTrainState::new(b.make_cursor(c));
                st.reset_params(&global);
                st
            })
            .collect();
        let slots = [0usize, 2, 3, 6, 8];
        let mut jobs: Vec<TrainJob> =
            slots.iter().map(|&s| TrainJob::new(s, 1 + s % 3, s)).collect();
        train_shard_parallel(&b, &global, &mut jobs, &mut states, 1).unwrap();
        for s in 0..n {
            let expect = if slots.contains(&s) { (1 + s % 3) as u64 } else { 0 };
            assert_eq!(states[s].steps, expect, "slot {s}");
        }
        for j in &jobs {
            assert_eq!(j.stats.batches, 1 + j.slot % 3);
        }
    }
}
