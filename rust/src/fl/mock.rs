//! Deterministic mock backend for simulator tests and benches that must
//! not depend on the XLA artifacts.
//!
//! The "model" is a scalar per parameter; training moves each client's
//! params toward a hidden per-client optimum (non-iid: optima differ),
//! loss is the distance to the client optimum, and evaluation measures
//! distance of the global model to the mean optimum — so convergence,
//! heterogeneity bias, and aggregation behave qualitatively like real FL
//! while being closed-form checkable.

use anyhow::Result;

use super::{BatchStats, TrainBackend};
use crate::util::rng::Rng;

pub struct MockBackend {
    pub dim: usize,
    /// hidden optimum per client
    pub optima: Vec<Vec<f32>>,
    /// mean optimum (the "true" model)
    pub target: Vec<f32>,
    pub lr: f32,
    pub steps: u64,
}

impl MockBackend {
    pub fn new(n_clients: usize, dim: usize, heterogeneity: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let base: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let optima: Vec<Vec<f32>> = (0..n_clients)
            .map(|_| {
                base.iter()
                    .map(|&b| b + heterogeneity * rng.normal() as f32)
                    .collect()
            })
            .collect();
        let mut target = vec![0.0f32; dim];
        for o in &optima {
            for (t, &v) in target.iter_mut().zip(o) {
                *t += v / n_clients as f32;
            }
        }
        MockBackend { dim, optima, target, lr: 0.2, steps: 0 }
    }

    fn dist(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }
}

impl TrainBackend for MockBackend {
    fn param_count(&self) -> usize {
        self.dim
    }

    fn init_params(&mut self, seed: i32) -> Result<Vec<f32>> {
        let mut rng = Rng::new(seed as u64 ^ 0xABCD);
        Ok((0..self.dim).map(|_| 3.0 + rng.normal() as f32).collect())
    }

    fn train_batches(
        &mut self,
        client: usize,
        params: &mut Vec<f32>,
        _global: &[f32],
        n_batches: usize,
    ) -> Result<BatchStats> {
        let opt = &self.optima[client];
        let mut loss_sum = 0.0;
        for _ in 0..n_batches {
            self.steps += 1;
            loss_sum += Self::dist(params, opt);
            for (p, &o) in params.iter_mut().zip(opt) {
                *p += self.lr * (o - *p);
            }
        }
        Ok(BatchStats {
            batches: n_batches,
            mean_loss: if n_batches > 0 {
                loss_sum / n_batches as f64
            } else {
                0.0
            },
            accuracy: 0.0,
        })
    }

    fn aggregate(&mut self, updates: &[Vec<f32>], weights: &[f32]) -> Result<Vec<f32>> {
        let total: f32 = weights.iter().sum();
        let mut out = vec![0.0f32; self.dim];
        for (u, &w) in updates.iter().zip(weights) {
            for (o, &v) in out.iter_mut().zip(u) {
                *o += v * w / total.max(1e-12);
            }
        }
        Ok(out)
    }

    fn evaluate(&mut self, params: &[f32]) -> Result<(f64, f64)> {
        let d = Self::dist(params, &self.target);
        // map distance to a pseudo-accuracy in (0, 1)
        Ok(((-d).exp().clamp(0.0, 1.0), d))
    }

    fn steps_executed(&self) -> u64 {
        self.steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_reduces_loss_and_converges() {
        let mut b = MockBackend::new(4, 8, 0.1, 1);
        let mut p = b.init_params(0).unwrap();
        let global = p.clone();
        let s1 = b.train_batches(0, &mut p, &global, 5).unwrap();
        let s2 = b.train_batches(0, &mut p, &global, 5).unwrap();
        assert!(s2.mean_loss < s1.mean_loss);
        assert_eq!(b.steps_executed(), 10);
    }

    #[test]
    fn aggregation_is_weighted_mean() {
        let mut b = MockBackend::new(2, 2, 0.0, 2);
        let out = b
            .aggregate(&[vec![0.0, 0.0], vec![2.0, 4.0]], &[1.0, 3.0])
            .unwrap();
        assert!((out[0] - 1.5).abs() < 1e-6);
        assert!((out[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn federated_loop_improves_eval() {
        let mut b = MockBackend::new(6, 8, 0.2, 3);
        let mut global = b.init_params(1).unwrap();
        let (acc0, _) = b.evaluate(&global).unwrap();
        for _round in 0..10 {
            let mut updates = Vec::new();
            for c in 0..6 {
                let mut p = global.clone();
                b.train_batches(c, &mut p, &global, 3).unwrap();
                updates.push(p);
            }
            global = b.aggregate(&updates, &[1.0; 6]).unwrap();
        }
        let (acc1, _) = b.evaluate(&global).unwrap();
        assert!(acc1 > acc0, "{acc0} -> {acc1}");
        assert!(acc1 > 0.5, "acc1={acc1}");
    }
}
