//! Deterministic mock backend for simulator tests and benches that must
//! not depend on the XLA artifacts.
//!
//! The "model" is a scalar per parameter; training moves each client's
//! params toward a hidden per-client optimum (non-iid: optima differ),
//! loss is the distance to the client optimum, and evaluation measures
//! distance of the global model to the mean optimum — so convergence,
//! heterogeneity bias, and aggregation behave qualitatively like real FL
//! while being closed-form checkable.
//!
//! The core is immutable after construction (`optima`/`target`/`lr`), so
//! the backend is `Sync` and opts into the shard fan-out: `train_shard`
//! delegates to [`train_shard_stealing`] once a shard has at least
//! `par_min_jobs` jobs (workers steal queued jobs, so uneven batch
//! counts don't serialise behind one monster job), and `aggregate`
//! chunks the parameter vector across workers once the model has at
//! least `par_agg_min` coordinates — both bit-identical to their serial
//! paths (each client state / output coordinate is touched by exactly
//! one worker running the same serial expression).

use anyhow::{anyhow, Result};

use super::{train_shard_stealing, BatchStats, ClientTrainState, TrainBackend, TrainJob};
use crate::util::par;
use crate::util::rng::Rng;

pub struct MockBackend {
    pub dim: usize,
    /// hidden optimum per client
    pub optima: Vec<Vec<f32>>,
    /// mean optimum (the "true" model)
    pub target: Vec<f32>,
    pub lr: f32,
    /// fan `train_shard` out across workers once a shard has at least
    /// this many jobs (mock batches are cheap; the default keeps
    /// evaluation-scale rounds serial — tests/benches pin 1 / usize::MAX
    /// to force both paths)
    pub par_min_jobs: usize,
    /// chunk `aggregate` across workers once the model has at least this
    /// many coordinates (same force-both-paths convention)
    pub par_agg_min: usize,
    /// worker count for the shard fan-out (`0` = auto); determinism
    /// tests pin 1/2/8 to prove the schedule never moves a bit
    pub par_workers: usize,
}

impl MockBackend {
    pub fn new(n_clients: usize, dim: usize, heterogeneity: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let base: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let optima: Vec<Vec<f32>> = (0..n_clients)
            .map(|_| {
                base.iter()
                    .map(|&b| b + heterogeneity * rng.normal() as f32)
                    .collect()
            })
            .collect();
        let mut target = vec![0.0f32; dim];
        for o in &optima {
            for (t, &v) in target.iter_mut().zip(o) {
                *t += v / n_clients as f32;
            }
        }
        MockBackend {
            dim,
            optima,
            target,
            lr: 0.2,
            par_min_jobs: 16,
            par_agg_min: 1 << 16,
            par_workers: 0,
        }
    }

    fn dist(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }
}

impl TrainBackend for MockBackend {
    type Cursor = ();

    fn param_count(&self) -> usize {
        self.dim
    }

    fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let mut rng = Rng::new(seed as u64 ^ 0xABCD);
        Ok((0..self.dim).map(|_| 3.0 + rng.normal() as f32).collect())
    }

    fn make_cursor(&self, _client: usize) -> Self::Cursor {}

    // the mock cursor is `()` — trivially checkpointable, so durable
    // runs (snapshots + crash-resume) work against this backend
    fn cursor_to_json(&self, _cursor: &Self::Cursor) -> Option<crate::util::json::Json> {
        Some(crate::util::json::Json::Null)
    }

    fn cursor_from_json(
        &self,
        _client: usize,
        _state: &crate::util::json::Json,
    ) -> Result<Self::Cursor> {
        Ok(())
    }

    fn train_batches(
        &self,
        client: usize,
        state: &mut ClientTrainState<()>,
        _global: &[f32],
        n_batches: usize,
    ) -> Result<BatchStats> {
        let opt = &self.optima[client];
        let mut loss_sum = 0.0;
        for _ in 0..n_batches {
            loss_sum += Self::dist(&state.params, opt);
            for (p, &o) in state.params.iter_mut().zip(opt) {
                *p += self.lr * (o - *p);
            }
        }
        Ok(BatchStats {
            batches: n_batches,
            mean_loss: if n_batches > 0 {
                loss_sum / n_batches as f64
            } else {
                0.0
            },
            accuracy: 0.0,
        })
    }

    fn train_shard(
        &self,
        global: &[f32],
        jobs: &mut [TrainJob],
        states: &mut [ClientTrainState<()>],
    ) -> Result<()> {
        train_shard_stealing(self, global, jobs, states, self.par_min_jobs, self.par_workers)
    }

    fn aggregate(&self, updates: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        if updates.len() != weights.len() {
            return Err(anyhow!(
                "aggregate: {} updates vs {} weights",
                updates.len(),
                weights.len()
            ));
        }
        if updates.is_empty() {
            return Err(anyhow!("aggregate called with no updates"));
        }
        for (i, u) in updates.iter().enumerate() {
            if u.len() != self.dim {
                return Err(anyhow!(
                    "update {i} has {} params, model dim is {}",
                    u.len(),
                    self.dim
                ));
            }
        }
        let total: f32 = weights.iter().sum();
        // zero total mass (all-zero sample counts) historically fell into
        // a silent `max(1e-12)` division that returned near-zero params,
        // destroying the model; `weighted_sum_into` falls back to the
        // unweighted mean instead
        let n = updates.len() as f32;
        let mut out = vec![0.0f32; self.dim];
        // chunked parallel FedAvg: every output coordinate is computed by
        // exactly one worker running the shared weighted-merge kernel
        // (`fl::tree::weighted_sum_into` — same per-update scale hoist and
        // update-order accumulation as the serial loop, and the same bits
        // the hierarchical aggregator produces) ⇒ byte-equal to serial
        par::par_fill_slice(&mut out, self.par_agg_min, |start, seg: &mut [f32]| {
            super::tree::weighted_sum_into(seg, start, updates, weights, total, n);
        });
        Ok(out)
    }

    fn evaluate(&self, params: &[f32]) -> Result<(f64, f64)> {
        let d = Self::dist(params, &self.target);
        // map distance to a pseudo-accuracy in (0, 1)
        Ok(((-d).exp().clamp(0.0, 1.0), d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn fresh_state(b: &MockBackend, client: usize, global: &[f32]) -> ClientTrainState<()> {
        let mut st = ClientTrainState::new(b.make_cursor(client));
        st.reset_params(global);
        st
    }

    #[test]
    fn training_reduces_loss_and_counts_steps() {
        let b = MockBackend::new(4, 8, 0.1, 1);
        let global = b.init_params(0).unwrap();
        let mut states = vec![fresh_state(&b, 0, &global)];
        let (s1, s2);
        {
            let mut jobs = [TrainJob::new(0, 5, 0)];
            b.train_shard(&global, &mut jobs, &mut states).unwrap();
            s1 = jobs[0].stats;
        }
        {
            let mut jobs = [TrainJob::new(0, 5, 0)];
            b.train_shard(&global, &mut jobs, &mut states).unwrap();
            s2 = jobs[0].stats;
        }
        assert!(s2.mean_loss < s1.mean_loss);
        assert_eq!(states[0].steps, 10);
    }

    #[test]
    fn aggregation_is_weighted_mean() {
        let b = MockBackend::new(2, 2, 0.0, 2);
        let out = b
            .aggregate(&[&[0.0, 0.0], &[2.0, 4.0]], &[1.0, 3.0])
            .unwrap();
        assert!((out[0] - 1.5).abs() < 1e-6);
        assert!((out[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn aggregate_rejects_empty_and_survives_zero_total() {
        let b = MockBackend::new(2, 2, 0.0, 2);
        assert!(b.aggregate(&[], &[]).is_err());
        assert!(b.aggregate(&[&[1.0, 2.0]], &[1.0, 2.0]).is_err());
        // all-zero weights: unweighted mean, not a ~zero model
        let out = b
            .aggregate(&[&[2.0, 0.0], &[4.0, 2.0]], &[0.0, 0.0])
            .unwrap();
        assert!((out[0] - 3.0).abs() < 1e-6);
        assert!((out[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn chunked_parallel_aggregate_is_byte_equal() {
        forall(20, |rng| {
            let dim = 1 + rng.below(600);
            let k = 1 + rng.below(7);
            let mut ser = MockBackend::new(2, dim, 0.3, 5);
            ser.par_agg_min = usize::MAX;
            let mut par_b = MockBackend::new(2, dim, 0.3, 5);
            par_b.par_agg_min = 1;
            let updates: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
                .collect();
            let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
            let weights: Vec<f32> =
                (0..k).map(|_| rng.range_f64(0.0, 9.0) as f32).collect();
            let a = ser.aggregate(&refs, &weights).unwrap();
            let b = par_b.aggregate(&refs, &weights).unwrap();
            let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "chunked aggregate diverged at dim {dim}");
        });
    }

    /// Satellite: sharded train of N clients equals the serial loop for
    /// seeded random schedules — params (bitwise), stats, and step
    /// counters all agree between the forced fan-out and the forced
    /// serial path, across multiple consecutive shards.
    #[test]
    fn sharded_train_equals_serial_loop_property() {
        forall(25, |rng| {
            let n_clients = 2 + rng.below(8);
            let dim = 2 + rng.below(24);
            let seed = rng.below(1_000) as u64;
            let mut ser = MockBackend::new(n_clients, dim, 0.3, seed);
            ser.par_min_jobs = usize::MAX; // serial shard path
            let mut par_b = MockBackend::new(n_clients, dim, 0.3, seed);
            par_b.par_min_jobs = 1; // forced fan-out
            let global = ser.init_params(seed as i32).unwrap();
            let mut st_ser: Vec<ClientTrainState<()>> =
                (0..n_clients).map(|c| fresh_state(&ser, c, &global)).collect();
            let mut st_par: Vec<ClientTrainState<()>> =
                (0..n_clients).map(|c| fresh_state(&par_b, c, &global)).collect();
            for _shard in 0..3 {
                // random schedule: a random subset of clients, each with
                // a random batch count (same schedule on both paths)
                let mut schedule: Vec<(usize, usize)> = Vec::new();
                for c in 0..n_clients {
                    if rng.f64() < 0.7 {
                        schedule.push((c, 1 + rng.below(5)));
                    }
                }
                let run = |b: &MockBackend,
                           states: &mut [ClientTrainState<()>]|
                 -> Vec<BatchStats> {
                    // index-based jobs: slot == client index into the
                    // full state arena (strictly increasing)
                    let mut jobs: Vec<TrainJob> = schedule
                        .iter()
                        .map(|&(c, n)| TrainJob::new(c, n, c))
                        .collect();
                    b.train_shard(&global, &mut jobs, states).unwrap();
                    jobs.iter().map(|j| j.stats).collect()
                };
                let stats_ser = run(&ser, &mut st_ser);
                let stats_par = run(&par_b, &mut st_par);
                for (a, b) in stats_ser.iter().zip(&stats_par) {
                    assert_eq!(a.batches, b.batches);
                    assert_eq!(a.mean_loss.to_bits(), b.mean_loss.to_bits());
                }
            }
            for (a, b) in st_ser.iter().zip(&st_par) {
                assert_eq!(a.steps, b.steps);
                let ab: Vec<u32> = a.params.iter().map(|x| x.to_bits()).collect();
                let bb: Vec<u32> = b.params.iter().map(|x| x.to_bits()).collect();
                assert_eq!(ab, bb, "params diverged");
            }
        });
    }

    /// Adversarial skew: one monster job (1000 batches) among trivial
    /// ones. The stolen shard must produce bitwise-identical params,
    /// stats and step counters at 1, 2 and 8 workers — and they must
    /// equal the serial loop.
    #[test]
    fn monster_job_shard_is_bitwise_stable_across_worker_counts() {
        let n_clients = 12usize;
        let dim = 16usize;
        let run = |par_min: usize, workers: usize| -> (Vec<Vec<u32>>, Vec<u64>, Vec<u64>) {
            let mut b = MockBackend::new(n_clients, dim, 0.3, 77);
            b.par_min_jobs = par_min;
            b.par_workers = workers;
            let global = b.init_params(7).unwrap();
            let mut states: Vec<ClientTrainState<()>> =
                (0..n_clients).map(|c| fresh_state(&b, c, &global)).collect();
            let mut jobs: Vec<TrainJob> = (0..n_clients)
                .map(|c| TrainJob::new(c, if c == 2 { 1000 } else { 1 + c % 3 }, c))
                .collect();
            b.train_shard(&global, &mut jobs, &mut states).unwrap();
            (
                states
                    .iter()
                    .map(|s| s.params.iter().map(|x| x.to_bits()).collect())
                    .collect(),
                states.iter().map(|s| s.steps).collect(),
                jobs.iter().map(|j| j.stats.mean_loss.to_bits()).collect(),
            )
        };
        let serial = run(usize::MAX, 0);
        for workers in [1usize, 2, 8] {
            let stolen = run(1, workers);
            assert_eq!(serial.0, stolen.0, "params diverged at {workers} workers");
            assert_eq!(serial.1, stolen.1, "steps diverged at {workers} workers");
            assert_eq!(serial.2, stolen.2, "stats diverged at {workers} workers");
        }
    }

    #[test]
    fn federated_loop_improves_eval() {
        let b = MockBackend::new(6, 8, 0.2, 3);
        let mut global = b.init_params(1).unwrap();
        let mut states: Vec<ClientTrainState<()>> =
            (0..6).map(|c| ClientTrainState::new(b.make_cursor(c))).collect();
        let (acc0, _) = b.evaluate(&global).unwrap();
        for _round in 0..10 {
            for st in states.iter_mut() {
                st.reset_params(&global);
            }
            let mut jobs: Vec<TrainJob> =
                (0..6).map(|c| TrainJob::new(c, 3, c)).collect();
            b.train_shard(&global, &mut jobs, &mut states).unwrap();
            let updates: Vec<&[f32]> =
                states.iter().map(|st| st.params.as_slice()).collect();
            global = b.aggregate(&updates, &[1.0; 6]).unwrap();
        }
        let (acc1, _) = b.evaluate(&global).unwrap();
        assert!(acc1 > acc0, "{acc0} -> {acc1}");
        assert!(acc1 > 0.5, "acc1={acc1}");
        // step accounting: 6 clients × 10 rounds × 3 batches
        let total: u64 = states.iter().map(|s| s.steps).sum();
        assert_eq!(total, 180);
    }
}
