//! Two-tier (hierarchical) FedAvg: one sub-aggregator per energy
//! domain, a serial root composer — the unit boundary for multi-process
//! aggregation at millions of clients.
//!
//! Flat FedAvg funnels every participant through one O(C·P) reduction
//! on a single thread-pool. Here each domain's sub-aggregator reduces
//! its own members into one `(partial_params, weight_mass)` pair, the
//! partials are filled *in parallel* with **work stealing**
//! (`util::par::steal` — domain populations are wildly uneven, so one
//! giant domain's row would pin a static contiguous split while the
//! other workers idled; per-worker gather scratch rides in the stealing
//! state), and the root composes them serially. The per-round arenas
//! (CSR grouping, masses, the g×P partial matrix) are reused across
//! rounds, so the steady state is allocation-free.
//!
//! # The canonical reduction order (the determinism invariant)
//!
//! f32 addition is not associative, so "tree == flat" can only be
//! *bitwise* if both sides execute the **same nested reduction** and
//! differ only in schedule. That canonical order is:
//!
//! 1. **Global scaling.** `total = Σ weights` (participant order, one
//!    left fold over ALL weights) and every update is scaled by
//!    `w / total` — or `1 / n` when the total mass is zero, matching
//!    the flat fold's unweighted-mean fallback. Scales are global, not
//!    per-domain: a domain partial is already in final units.
//! 2. **Leaf tier.** For each domain shard, in ascending domain-id
//!    order: accumulate `Σ scale_i · update_i` over the shard's members
//!    in ascending participant order (one row of the partial matrix,
//!    accumulated left to right exactly like the flat fold would).
//! 3. **Root tier.** `out = partial_0; out += partial_1; …` serially in
//!    ascending domain-id order, regardless of which shard finished
//!    first.
//!
//! [`AggMode::Flat`] executes that reduction serially (the oracle);
//! [`AggMode::Tree`] fills the leaf rows in parallel. Each row is
//! written by exactly one worker evaluating the same serial expression,
//! and the root compose is serial in both modes, so the two schedules
//! write identical bytes — property-tested here over random partitions
//! and gated end to end (engine test matrix, `benches/endtoend.rs
//! --tree`, `ci.sh --quick`). With a single domain the whole reduction
//! degenerates to the historical flat fold of `fl::mock`, bit for bit.
//!
//! [`weighted_sum_into`] is the ONE weighted-merge kernel: the mock
//! backend's chunked flat FedAvg and the leaf tier here both call it,
//! and `fl::backend`'s >agg_k composition shares [`chunk_masses`] — so
//! a scaling or fallback change cannot drift between implementations.
//!
//! # In-process eager shards
//!
//! In a multi-process deployment each domain shard would aggregate the
//! moment its last member's `UpdateSubmitted` lands (the coordinator
//! FSM tracks exactly that — `RoundFsm::assign_domains` /
//! `shards_complete`). In-process we *record* shard completion for
//! observability but compute the partials at round close: submitted
//! slots keep training until their progress cap, so params mutate after
//! submission and an eagerly-materialised partial would diverge from
//! the legacy loop. The scheduling freedom is the multi-process hook;
//! the algebra (and the bits) are fixed by the canonical order above.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::util::obs;
use crate::util::par;
use crate::util::par::thresholds;

/// Which aggregation schedule the engine uses. Both execute the
/// canonical reduction of the module docs and are bitwise-identical;
/// `Flat` is the serial oracle, `Tree` the parallel default.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggMode {
    /// Serial schedule of the canonical two-tier reduction (the
    /// oracle the property tests and bench gates compare against).
    Flat,
    /// Per-domain partial rows filled in parallel (the default).
    Tree,
}

/// The ONE weighted-merge kernel: accumulate `Σ scale_i · update_i`
/// into `seg` (= coordinates `start..start + seg.len()` of the output),
/// where `scale_i = w_i / total`, or `1 / n_total` when the total mass
/// is zero (unweighted-mean fallback — all-zero sample counts must not
/// zero the model). The per-update scale is hoisted out of the
/// coordinate loop and updates accumulate left to right, so every
/// caller — the mock backend's chunked flat FedAvg, the tree's leaf
/// tier — produces the same bits for the same (updates, weights) slice.
#[inline]
pub fn weighted_sum_into(
    seg: &mut [f32],
    start: usize,
    updates: &[&[f32]],
    weights: &[f32],
    total: f32,
    n_total: f32,
) {
    for (u, &w) in updates.iter().zip(weights) {
        let scale = if total > 0.0 { w / total } else { 1.0 / n_total };
        for (o, &v) in seg.iter_mut().zip(&u[start..start + seg.len()]) {
            *o += v * scale;
        }
    }
}

/// Per-chunk weight masses for composed (multi-level) FedAvg: one
/// pre-sized pass pushing `Σ chunk` for each `k`-sized chunk of
/// `weights` into `out` (cleared first). Shared by the XLA backend's
/// >agg_k composition so partial-mass bookkeeping cannot drift from the
/// tree's per-domain masses.
pub fn chunk_masses(weights: &[f32], k: usize, out: &mut Vec<f32>) {
    out.clear();
    if weights.is_empty() {
        return;
    }
    let k = k.max(1);
    out.reserve((weights.len() + k - 1) / k);
    for chunk in weights.chunks(k) {
        out.push(chunk.iter().sum());
    }
}

/// The two-tier aggregator. One instance lives on the simulation for
/// its whole run: every buffer below is an arena that keeps its
/// capacity across rounds, so steady-state aggregation allocates
/// nothing (gated by the `arena_bytes` plateau in the endtoend bench).
pub struct TreeAggregator {
    /// distinct participant domain ids, ascending — the canonical
    /// composition order of the root tier
    group_doms: Vec<usize>,
    /// CSR offsets into `members` (`group_doms.len() + 1` entries)
    offsets: Vec<u32>,
    /// participant indices grouped by domain, ascending within a group
    members: Vec<u32>,
    /// counting-sort scratch, indexed by domain id (dense path)
    counts: Vec<u32>,
    /// per-group weight mass — the `weight_mass` half of the
    /// `(partial_params, weight_mass)` a sub-aggregator would ship
    masses: Vec<f32>,
    /// g × dim partial-parameter matrix (row = one domain partial)
    partials: Vec<f32>,
    /// fan the leaf tier out once a round spans at least this many
    /// domain groups… (tests pin 1 / usize::MAX to force both paths)
    pub par_groups_min: usize,
    /// …AND the participants × parameters product reaches this (a
    /// handful of tiny rows is cheaper to fill inline than to spawn
    /// for); both gates must pass
    pub par_work_min: usize,
    /// worker count for the leaf-tier fill (`0` = auto, i.e.
    /// `par::threads()`); tests and benches pin 1/2/8 to prove the
    /// schedule never moves a bit
    pub par_workers: usize,
    /// rounds aggregated through this instance
    pub rounds: u64,
    /// domain shards reduced across all rounds
    pub shards_aggregated: u64,
    /// cumulative leaf-tier scheduling telemetry (steal counts are the
    /// bench's evidence that skewed rows actually redistribute; never
    /// correctness-bearing)
    pub steal_stats: par::steal::StealStats,
    peak_arena: usize,
}

impl Default for TreeAggregator {
    fn default() -> Self {
        Self::new()
    }
}

impl TreeAggregator {
    pub fn new() -> Self {
        TreeAggregator {
            group_doms: Vec::new(),
            offsets: Vec::new(),
            members: Vec::new(),
            counts: Vec::new(),
            masses: Vec::new(),
            partials: Vec::new(),
            par_groups_min: thresholds::TREE_GROUPS,
            par_work_min: thresholds::TREE_WORK,
            par_workers: 0,
            rounds: 0,
            shards_aggregated: 0,
            steal_stats: par::steal::StealStats::default(),
            peak_arena: 0,
        }
    }

    /// Domain groups of the most recent `aggregate_into` call.
    pub fn groups(&self) -> usize {
        self.group_doms.len()
    }

    /// Distinct domain ids of the most recent call, ascending (the
    /// canonical composition order).
    pub fn group_domains(&self) -> &[usize] {
        &self.group_doms
    }

    /// Per-group weight masses of the most recent call, in
    /// `group_domains` order.
    pub fn group_masses(&self) -> &[f32] {
        &self.masses
    }

    /// Current arena footprint (capacity, not length — what the
    /// allocator actually holds between rounds). The endtoend bench
    /// uses this as its peak-RSS proxy.
    pub fn arena_bytes(&self) -> usize {
        self.partials.capacity() * 4
            + self.masses.capacity() * 4
            + self.members.capacity() * 4
            + self.offsets.capacity() * 4
            + self.counts.capacity() * 4
            + self.group_doms.capacity() * std::mem::size_of::<usize>()
    }

    /// High-water arena footprint across all rounds so far.
    pub fn peak_arena_bytes(&self) -> usize {
        self.peak_arena
    }

    /// Group participants by domain into the CSR arenas. Canonical
    /// structure either way: distinct domains ascending, members in
    /// ascending participant order within each group. Dense domain ids
    /// take an O(n + max_id) counting sort; wildly sparse ids (beyond
    /// ~4·n) fall back to an ordered map.
    fn build_groups(&mut self, domains: &[usize]) {
        let n = domains.len();
        self.group_doms.clear();
        self.offsets.clear();
        self.members.clear();
        let max_d = domains.iter().copied().max().unwrap_or(0);
        if max_d < n.saturating_mul(4).saturating_add(1024) {
            self.counts.clear();
            self.counts.resize(max_d + 1, 0);
            for &d in domains {
                self.counts[d] += 1;
            }
            let mut cum = 0u32;
            for d in 0..=max_d {
                let c = self.counts[d];
                if c > 0 {
                    self.group_doms.push(d);
                    self.offsets.push(cum);
                }
                self.counts[d] = cum; // becomes the domain's write cursor
                cum += c;
            }
            self.offsets.push(cum);
            self.members.clear();
            self.members.resize(n, 0);
            for (p, &d) in domains.iter().enumerate() {
                let pos = self.counts[d] as usize;
                self.members[pos] = p as u32;
                self.counts[d] += 1;
            }
        } else {
            let mut map: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
            for (p, &d) in domains.iter().enumerate() {
                map.entry(d).or_default().push(p as u32);
            }
            let mut cum = 0u32;
            for (d, mem) in map {
                self.group_doms.push(d);
                self.offsets.push(cum);
                cum += mem.len() as u32;
                self.members.extend_from_slice(&mem);
            }
            self.offsets.push(cum);
        }
    }

    /// Aggregate `updates` (weighted by `weights`, sharded by
    /// `domains`) into `out`, replacing its contents. Both modes
    /// execute the canonical reduction of the module docs; `Tree` fills
    /// the per-domain partial rows in parallel (subject to the
    /// `par_groups_min` / `par_work_min` gates), `Flat` serially.
    pub fn aggregate_into(
        &mut self,
        mode: AggMode,
        domains: &[usize],
        updates: &[&[f32]],
        weights: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let n = updates.len();
        if n != weights.len() || n != domains.len() {
            return Err(anyhow!(
                "aggregate: {} updates vs {} weights vs {} domains",
                n,
                weights.len(),
                domains.len()
            ));
        }
        if n == 0 {
            return Err(anyhow!("aggregate called with no updates"));
        }
        let dim = updates[0].len();
        for (i, u) in updates.iter().enumerate() {
            if u.len() != dim {
                return Err(anyhow!(
                    "update {i} has {} params, update 0 has {dim}",
                    u.len()
                ));
            }
        }
        debug_assert!(n < u32::MAX as usize);

        self.build_groups(domains);
        let g = self.group_doms.len();

        // canonical step 1: ONE global total over all weights in
        // participant order (identical expression to the flat fold),
        // unweighted-mean fallback on zero mass
        let total: f32 = weights.iter().sum();
        let n_total = n as f32;

        // the weight_mass half of each domain's emission (members in
        // participant order, like the partial itself)
        self.masses.clear();
        for gi in 0..g {
            let lo = self.offsets[gi] as usize;
            let hi = self.offsets[gi + 1] as usize;
            let mut m = 0.0f32;
            for &p in &self.members[lo..hi] {
                m += weights[p as usize];
            }
            self.masses.push(m);
        }

        // canonical step 2, the leaf tier: Flat pins the row fill
        // serial; Tree fans rows out (with stealing — domain
        // populations are skewed) once both gates pass. Either way each
        // row is one worker running the same serial expression.
        let min_rows = match mode {
            AggMode::Flat => usize::MAX,
            AggMode::Tree => {
                if g >= self.par_groups_min
                    && n.saturating_mul(dim) >= self.par_work_min
                {
                    1
                } else {
                    usize::MAX
                }
            }
        };
        if self.partials.capacity() >= g * dim {
            obs::add(obs::Ctr::TreeArenaReuses, 1);
        } else {
            obs::add(obs::Ctr::TreeArenaGrows, 1);
        }
        self.partials.clear();
        self.partials.resize(g * dim, 0.0);
        let offsets = &self.offsets;
        let members = &self.members;
        let fill_stats = par::steal::steal_fill_rows_scratch(
            &mut self.partials,
            dim,
            min_rows,
            self.par_workers,
            || (Vec::new(), Vec::new()),
            |gi, row, scratch: &mut (Vec<_>, Vec<_>)| {
                let _fill_timer = obs::timer(obs::Hist::ShardFillNs);
                let (gu, gw) = scratch;
                gu.clear();
                gw.clear();
                let lo = offsets[gi] as usize;
                let hi = offsets[gi + 1] as usize;
                for &p in &members[lo..hi] {
                    gu.push(updates[p as usize]);
                    gw.push(weights[p as usize]);
                }
                weighted_sum_into(row, 0, gu, gw, total, n_total);
            },
        );
        self.steal_stats.absorb(fill_stats);

        // canonical step 3, the root tier: serial compose in ascending
        // domain-id order on both schedules (copy-then-add so a single
        // domain reproduces the flat fold exactly, -0.0 bits included)
        out.clear();
        out.extend_from_slice(&self.partials[..dim]);
        for gi in 1..g {
            let row = &self.partials[gi * dim..(gi + 1) * dim];
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }

        self.rounds += 1;
        self.shards_aggregated += g as u64;
        obs::add(obs::Ctr::TreeAggregations, 1);
        obs::add(obs::Ctr::TreeShards, g as u64);
        let bytes = self.arena_bytes();
        if bytes > self.peak_arena {
            self.peak_arena = bytes;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::{MockBackend, TrainBackend};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn random_instance(rng: &mut Rng) -> (Vec<Vec<f32>>, Vec<f32>, Vec<usize>) {
        let n = 1 + rng.below(40);
        let dim = 1 + rng.below(64);
        let updates: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let weights: Vec<f32> = if rng.f64() < 0.1 {
            vec![0.0; n] // zero-mass edge: unweighted-mean fallback
        } else {
            (0..n).map(|_| rng.range_f64(0.0, 9.0) as f32).collect()
        };
        let domains: Vec<usize> = match rng.below(4) {
            0 => vec![rng.below(5); n],             // one domain
            1 => (0..n).collect(),                  // all singleton
            2 => {
                let d = 1 + rng.below(8);
                (0..n).map(|p| (p * 7 + 3) % d).collect() // dense, gappy
            }
            _ => (0..n).map(|p| (p % 5) * 1_000_003).collect(), // sparse ids
        };
        (updates, weights, domains)
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// THE tentpole property: the parallel tree schedule is bitwise
    /// equal to the serial flat oracle across random domain partitions
    /// — one-domain, all-singleton, gappy (empty-domain) and sparse-id
    /// edges included, zero-mass weights included.
    #[test]
    fn tree_equals_flat_bitwise_over_random_partitions() {
        forall(60, |rng| {
            let (updates, weights, domains) = random_instance(rng);
            let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
            let mut flat = TreeAggregator::new();
            let mut tree = TreeAggregator::new();
            tree.par_groups_min = 1; // force the parallel schedule
            tree.par_work_min = 0;
            let mut out_f = Vec::new();
            let mut out_t = Vec::new();
            flat.aggregate_into(AggMode::Flat, &domains, &refs, &weights, &mut out_f)
                .unwrap();
            tree.aggregate_into(AggMode::Tree, &domains, &refs, &weights, &mut out_t)
                .unwrap();
            assert_eq!(
                bits(&out_f),
                bits(&out_t),
                "tree != flat for domains {domains:?}"
            );
            assert_eq!(flat.groups(), tree.groups());
            assert_eq!(flat.group_domains(), tree.group_domains());
            assert_eq!(bits(flat.group_masses()), bits(tree.group_masses()));
        });
    }

    /// Adversarial skew: one giant domain holds ~90% of participants,
    /// the rest are singletons — the stolen row fill must still write
    /// exactly the flat oracle's bytes (partial matrix AND composed
    /// output) at 1, 2 and 8 workers.
    #[test]
    fn giant_domain_skew_is_bitwise_stable_across_worker_counts() {
        let mut rng = Rng::new(0xD00D);
        let n = 400usize;
        let dim = 24usize;
        let updates: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
        let weights: Vec<f32> = (0..n).map(|_| rng.range_f64(0.1, 5.0) as f32).collect();
        // participants 0..360 pile into domain 0; the rest get their own
        let domains: Vec<usize> =
            (0..n).map(|p| if p < 360 { 0 } else { p - 359 }).collect();
        let mut flat = TreeAggregator::new();
        let mut out_flat = Vec::new();
        flat.aggregate_into(AggMode::Flat, &domains, &refs, &weights, &mut out_flat)
            .unwrap();
        let oracle_partials = bits(&flat.partials);
        for workers in [1usize, 2, 8] {
            let mut tree = TreeAggregator::new();
            tree.par_groups_min = 1;
            tree.par_work_min = 0;
            tree.par_workers = workers;
            let mut out = Vec::new();
            tree.aggregate_into(AggMode::Tree, &domains, &refs, &weights, &mut out)
                .unwrap();
            assert_eq!(bits(&out_flat), bits(&out), "out diverged at {workers} workers");
            assert_eq!(
                oracle_partials,
                bits(&tree.partials),
                "partial matrix diverged at {workers} workers"
            );
            assert_eq!(tree.steal_stats.workers, workers.min(tree.groups()).max(1));
        }
    }

    /// With one domain the canonical reduction degenerates to the
    /// historical flat fold — bitwise equal to `MockBackend::aggregate`
    /// (which routes through the same `weighted_sum_into` kernel).
    #[test]
    fn single_domain_reproduces_mock_flat_fold_bitwise() {
        forall(25, |rng| {
            let n = 1 + rng.below(12);
            let dim = 1 + rng.below(48);
            let backend = MockBackend::new(n, dim, 0.3, 11);
            let updates: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
                .collect();
            let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
            let weights: Vec<f32> =
                (0..n).map(|_| rng.range_f64(0.0, 9.0) as f32).collect();
            let expected = backend.aggregate(&refs, &weights).unwrap();
            let domains = vec![7usize; n];
            let mut agg = TreeAggregator::new();
            for mode in [AggMode::Flat, AggMode::Tree] {
                let mut out = Vec::new();
                agg.aggregate_into(mode, &domains, &refs, &weights, &mut out)
                    .unwrap();
                assert_eq!(bits(&expected), bits(&out), "{mode:?} != mock flat");
            }
        });
    }

    #[test]
    fn zero_total_mass_falls_back_to_unweighted_mean() {
        let updates: [&[f32]; 2] = [&[2.0, 0.0], &[4.0, 2.0]];
        let mut agg = TreeAggregator::new();
        let mut out = Vec::new();
        agg.aggregate_into(AggMode::Tree, &[0, 1], &updates, &[0.0, 0.0], &mut out)
            .unwrap();
        assert!((out[0] - 3.0).abs() < 1e-6);
        assert!((out[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn malformed_inputs_are_structured_errors() {
        let mut agg = TreeAggregator::new();
        let mut out = Vec::new();
        let u: [&[f32]; 2] = [&[1.0, 2.0], &[3.0]];
        assert!(agg
            .aggregate_into(AggMode::Tree, &[], &[], &[], &mut out)
            .is_err());
        assert!(agg
            .aggregate_into(AggMode::Tree, &[0], &[&[1.0][..]], &[1.0, 2.0], &mut out)
            .is_err());
        assert!(agg
            .aggregate_into(AggMode::Tree, &[0, 1], &u, &[1.0, 1.0], &mut out)
            .is_err());
    }

    /// Gappy domain ids (groups 2/5/9, nothing in between) keep the
    /// canonical ascending order and participant-order members.
    #[test]
    fn gappy_domains_compose_in_ascending_id_order() {
        let updates: [&[f32]; 4] = [&[1.0], &[2.0], &[4.0], &[8.0]];
        let weights = [1.0f32, 1.0, 1.0, 1.0];
        let domains = [9usize, 2, 2, 5];
        let mut agg = TreeAggregator::new();
        let mut out = Vec::new();
        agg.aggregate_into(AggMode::Flat, &domains, &updates, &weights, &mut out)
            .unwrap();
        assert_eq!(agg.group_domains(), &[2, 5, 9]);
        assert_eq!(agg.group_masses(), &[2.0, 1.0, 1.0]);
        assert!((out[0] - 15.0 / 4.0).abs() < 1e-6);
    }

    /// Arenas are reused: a second identical round leaves the footprint
    /// unchanged (allocation-free steady state) and the stats advance.
    #[test]
    fn arena_plateaus_and_stats_accumulate() {
        let updates: [&[f32]; 3] = [&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]];
        let weights = [1.0f32, 2.0, 3.0];
        let domains = [0usize, 1, 0];
        let mut agg = TreeAggregator::new();
        let mut out = Vec::new();
        agg.aggregate_into(AggMode::Tree, &domains, &updates, &weights, &mut out)
            .unwrap();
        let first = agg.arena_bytes();
        assert!(first > 0);
        let mut out2 = Vec::new();
        agg.aggregate_into(AggMode::Tree, &domains, &updates, &weights, &mut out2)
            .unwrap();
        assert_eq!(agg.arena_bytes(), first, "steady state reallocated");
        assert_eq!(agg.peak_arena_bytes(), first);
        assert_eq!(agg.rounds, 2);
        assert_eq!(agg.shards_aggregated, 4);
        assert_eq!(bits(&out), bits(&out2));
    }

    #[test]
    fn chunk_masses_sums_per_chunk() {
        let mut out = vec![99.0f32];
        chunk_masses(&[1.0, 2.0, 3.0, 4.0, 5.0], 2, &mut out);
        assert_eq!(out, vec![3.0, 7.0, 5.0]);
        chunk_masses(&[1.0, 2.0], 0, &mut out); // k clamps to 1
        assert_eq!(out, vec![1.0, 2.0]);
        chunk_masses(&[], 4, &mut out);
        assert!(out.is_empty());
    }
}
