//! Client-churn / outage model: seeded per-client availability windows
//! injected into the simulation loop.
//!
//! The paper assumes registered clients stay reachable; at fleet scale
//! (and in the green-FL follow-up work this repo's PAPERS.md collects)
//! devices drop out — network loss, local jobs, users unplugging
//! hardware. The model here is a two-state Markov process per client,
//! discretised to simulation steps: an online client goes offline with a
//! per-step probability calibrated from `outages_per_day`, and an
//! offline client comes back with a per-step probability calibrated from
//! `mean_outage_min` (geometric dwell time). Windows are materialised
//! once at build time as sorted, disjoint `[start, end)` step ranges so
//! the engine's per-step check is a cheap scan of a short list.
//!
//! Every client draws from its own `Rng` stream derived from
//! `seed ^ CHURN_STREAM ^ hash(client)`, independent of the environment
//! builder's RNG — adding churn to a spec cannot perturb the generated
//! traces, and a spec without churn is bit-identical to the legacy
//! builder (the equivalence gate in `scenario::tests` relies on this).
//!
//! Enforcement: under the event-driven engine (the default,
//! `sim::ExecMode::Fsm`) each window overlapping a round is translated
//! into `Dropout`/`Rejoin` events on the coordinator's queue — churn is
//! just one event source among several ([`crate::sim::chaos`] is
//! another), and the round state machine composes overlapping windows
//! via per-client offline depth. The legacy loop checks windows
//! directly (`online_at`); both paths exclude an offline client from
//! the active set before power requests are built, so it is granted
//! **no energy and no batches** for the step — the unit tests below pin
//! that down end to end. Selection intentionally stays unaware of
//! future outages (the server cannot forecast churn); a selected client
//! that drops mid-round simply stalls and, if it misses `m_min`, is
//! discarded as a straggler, feeding the campaign's waste metric. The
//! `FedZero ca` / `SemiSync ca` strategies react to the *observed*
//! dropout rate by over-selecting ([`crate::selection::adaptive`]).

use anyhow::{bail, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Stream tag separating churn draws from every other consumer of the
/// experiment seed.
const CHURN_STREAM: u64 = 0x43_48_55_52_4E; // "CHURN"

/// Churn axis of an [`super::EnvSpec`].
#[derive(Clone, Copy, Debug)]
pub struct ChurnSpec {
    /// expected outage events per client per simulated day
    pub outages_per_day: f64,
    /// mean outage duration in minutes (geometric dwell)
    pub mean_outage_min: f64,
}

impl ChurnSpec {
    pub fn from_json(j: &Json) -> Result<ChurnSpec> {
        let spec = ChurnSpec {
            outages_per_day: j.get("outages_per_day").and_then(|v| v.as_f64()).unwrap_or(1.0),
            mean_outage_min: j.get("mean_outage_min").and_then(|v| v.as_f64()).unwrap_or(60.0),
        };
        if spec.outages_per_day < 0.0 || spec.mean_outage_min <= 0.0 {
            bail!(
                "churn needs outages_per_day >= 0 and mean_outage_min > 0, got {spec:?}"
            );
        }
        Ok(spec)
    }

    /// Materialise per-client outage windows `[start, end)` over the
    /// horizon. Deterministic in `(self, n_clients, horizon,
    /// step_minutes, seed)`; every client uses an independent stream.
    pub fn generate(
        &self,
        n_clients: usize,
        horizon: usize,
        step_minutes: f64,
        seed: u64,
    ) -> Vec<Vec<(usize, usize)>> {
        let p_start =
            (self.outages_per_day * step_minutes / (24.0 * 60.0)).clamp(0.0, 1.0);
        // geometric dwell with mean = mean_outage_min (floored to one step)
        let p_end = (step_minutes / self.mean_outage_min.max(step_minutes)).clamp(0.0, 1.0);
        (0..n_clients)
            .map(|i| {
                let mut rng = Rng::new(
                    seed ^ CHURN_STREAM ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15),
                );
                let mut windows = Vec::new();
                let mut t = 0usize;
                while t < horizon {
                    if rng.bool(p_start) {
                        let start = t;
                        t += 1;
                        while t < horizon && !rng.bool(p_end) {
                            t += 1;
                        }
                        windows.push((start, t.min(horizon)));
                    }
                    t += 1;
                }
                windows
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ChurnSpec {
        ChurnSpec { outages_per_day: 3.0, mean_outage_min: 60.0 }
    }

    #[test]
    fn windows_are_deterministic_sorted_and_disjoint() {
        let a = spec().generate(20, 5_000, 1.0, 42);
        let b = spec().generate(20, 5_000, 1.0, 42);
        assert_eq!(a, b);
        for ws in &a {
            let mut last_end = 0usize;
            for &(s, e) in ws {
                assert!(s < e, "empty window ({s},{e})");
                assert!(e <= 5_000);
                assert!(s >= last_end, "overlapping windows");
                last_end = e;
            }
        }
        // a different seed produces different schedules
        let c = spec().generate(20, 5_000, 1.0, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn outage_rate_and_duration_track_the_spec() {
        // 3 outages/day × ~60 min each over many client-days
        let horizon = 10 * 1440;
        let ws = spec().generate(50, horizon, 1.0, 7);
        let events: usize = ws.iter().map(|w| w.len()).sum();
        let offline: usize =
            ws.iter().flat_map(|w| w.iter().map(|&(s, e)| e - s)).sum();
        let days = 50.0 * 10.0;
        let per_day = events as f64 / days;
        assert!((1.5..5.0).contains(&per_day), "events/day {per_day}");
        let mean_min = offline as f64 / events.max(1) as f64;
        assert!((30.0..100.0).contains(&mean_min), "mean outage {mean_min} min");
    }

    #[test]
    fn zero_rate_means_no_outages() {
        let ws = ChurnSpec { outages_per_day: 0.0, mean_outage_min: 60.0 }
            .generate(10, 2_000, 1.0, 1);
        assert!(ws.iter().all(|w| w.is_empty()));
    }

    #[test]
    fn clients_are_independent_streams() {
        let ws = spec().generate(8, 8_000, 1.0, 9);
        // no two clients share an identical schedule (astronomically
        // unlikely with independent streams; equality would mean the
        // stream derivation collapsed)
        for i in 0..ws.len() {
            for j in i + 1..ws.len() {
                assert_ne!(ws[i], ws[j], "clients {i} and {j} share a schedule");
            }
        }
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let j = Json::parse(r#"{"outages_per_day": 2.5, "mean_outage_min": 30}"#).unwrap();
        let s = ChurnSpec::from_json(&j).unwrap();
        assert_eq!(s.outages_per_day, 2.5);
        assert_eq!(s.mean_outage_min, 30.0);
        let bad = Json::parse(r#"{"mean_outage_min": 0}"#).unwrap();
        assert!(ChurnSpec::from_json(&bad).is_err());
    }
}
