//! The declarative environment spec: everything that shapes one simulated
//! world, parsed from JSON (`util::json`, no external crates). See the
//! module docs of [`crate::scenario`] for the full schema.

use anyhow::{anyhow, bail, Result};

use crate::config::Scenario;
use crate::sim::ChaosSpec;
use crate::trace::forecast::ErrorLevel;
use crate::trace::solar::{self, Site};
use crate::util::json::Json;

use super::churn::ChurnSpec;

/// Which solar sites back the power domains: one of the paper's presets
/// or a fully parameterized custom list.
#[derive(Clone, Debug)]
pub enum SiteSet {
    /// the ten globally distributed cities (paper global scenario)
    Global,
    /// the ten German cities (paper co-located scenario)
    Colocated,
    Custom(Vec<Site>),
}

impl SiteSet {
    pub fn sites(&self) -> Vec<Site> {
        match self {
            SiteSet::Global => solar::global_sites(),
            SiteSet::Colocated => solar::colocated_sites(),
            SiteSet::Custom(sites) => sites.clone(),
        }
    }

    pub fn name(&self) -> &str {
        match self {
            SiteSet::Global => "global",
            SiteSet::Colocated => "co-located",
            SiteSet::Custom(_) => "custom",
        }
    }

    /// paper dates: June 8 (global) / July 15 (co-located); custom site
    /// lists default to the global date unless the spec overrides it
    pub fn default_start_day(&self) -> u32 {
        match self {
            SiteSet::Colocated => 196,
            _ => 159,
        }
    }

    /// co-located sites share one regional cloud process (paper Fig 2)
    pub fn default_regional_clouds(&self) -> Option<f64> {
        match self {
            SiteSet::Colocated => Some(0.4),
            _ => None,
        }
    }
}

/// Overrides for the realistic forecast-error model beyond the coarse
/// [`ErrorLevel`] switch (per-axis robustness sweeps: Fig-7 style but
/// with a controllable error magnitude).
#[derive(Clone, Copy, Debug)]
pub struct ErrorParams {
    /// relative error std at 1 h lead
    pub sigma0: f64,
    /// saturation of the relative error
    pub sigma_max: f64,
    /// multiplicative bias
    pub bias: f64,
}

/// Declarative description of one simulated environment — the shape
/// knobs that used to be hard-coded in `config::Scenario`. Per-run knobs
/// (client count, days, seed, coarse error levels) stay in [`EnvConfig`];
/// the builtin specs plus a default `EnvConfig` reproduce the legacy
/// `config::build` output bit for bit (gated by `scenario::tests`).
#[derive(Clone, Debug)]
pub struct EnvSpec {
    pub sites: SiteSet,
    /// start day-of-year override (None = the site set's paper date)
    pub start_day_of_year: Option<u32>,
    /// shared regional cloud process depth (None = independent clouds;
    /// builtin co-located: Some(0.4))
    pub regional_clouds: Option<f64>,
    /// nameplate capacity per domain in W: one entry broadcasts to all
    /// domains (paper: [800]), or one entry per domain
    pub capacity_w: Vec<f64>,
    /// battery capacity per domain in Wh: empty = no storage, one entry
    /// broadcasts, or one entry per domain (see `scenario::apply_battery`)
    pub battery_wh: Vec<f64>,
    /// battery sustain threshold as a fraction of the domain capacity
    pub battery_sustain_frac: f64,
    /// device-type mix weights [small, mid, large]; None = the paper's
    /// uniform draw (exactly the legacy RNG sequence)
    pub device_mix: Option<[f64; 3]>,
    /// overrides for the energy forecasters' realistic-error parameters
    pub energy_error_params: Option<ErrorParams>,
    /// client-churn model (None = full availability, the paper's setting)
    pub churn: Option<ChurnSpec>,
    /// round-scoped fault injection (None = no faults, the paper's
    /// setting). Applied at simulation time, NOT during the environment
    /// build — deliberately excluded from [`EnvSpec::cache_key`] so
    /// campaign cells differing only in chaos share a memoised build.
    pub chaos: Option<ChaosSpec>,
}

impl EnvSpec {
    /// The builtin spec for a legacy paper scenario — bit-identical to
    /// `config::build` by construction.
    pub fn builtin(scenario: Scenario) -> EnvSpec {
        match scenario {
            Scenario::Global => EnvSpec::global(),
            Scenario::Colocated => EnvSpec::colocated(),
        }
    }

    pub fn global() -> EnvSpec {
        EnvSpec {
            sites: SiteSet::Global,
            start_day_of_year: None,
            regional_clouds: None,
            capacity_w: vec![800.0],
            battery_wh: Vec::new(),
            battery_sustain_frac: 0.25,
            device_mix: None,
            energy_error_params: None,
            churn: None,
            chaos: None,
        }
    }

    pub fn colocated() -> EnvSpec {
        EnvSpec { sites: SiteSet::Colocated, regional_clouds: Some(0.4), ..EnvSpec::global() }
    }

    pub fn start_day(&self) -> u32 {
        self.start_day_of_year.unwrap_or_else(|| self.sites.default_start_day())
    }

    /// Nameplate capacity of domain `p` (broadcast or per-domain).
    pub fn capacity_of(&self, p: usize) -> f64 {
        match self.capacity_w.len() {
            0 => 800.0,
            1 => self.capacity_w[0],
            _ => self.capacity_w[p],
        }
    }

    /// Battery capacity of domain `p`, Wh (0 = none).
    pub fn battery_of(&self, p: usize) -> f64 {
        match self.battery_wh.len() {
            0 => 0.0,
            1 => self.battery_wh[0],
            _ => self.battery_wh[p],
        }
    }

    /// Validate vector knob lengths against the site count.
    pub fn validate(&self) -> Result<()> {
        let d = self.sites.sites().len();
        if d == 0 {
            bail!("spec has no sites");
        }
        for (name, v) in [("capacity_w", &self.capacity_w), ("battery_wh", &self.battery_wh)] {
            if v.len() > 1 && v.len() != d {
                bail!("{name} has {} entries for {d} domains (want 1 or {d})", v.len());
            }
        }
        if let Some(mix) = self.device_mix {
            if mix.iter().any(|&w| w < 0.0) || mix.iter().sum::<f64>() <= 0.0 {
                bail!("device_mix weights must be non-negative with a positive sum");
            }
        }
        Ok(())
    }

    /// Parse from the JSON schema documented in the module docs.
    pub fn from_json(j: &Json) -> Result<EnvSpec> {
        let mut spec = EnvSpec::global();
        let sites = match j.get("sites") {
            None => SiteSet::Global,
            Some(Json::Str(s)) => match s.as_str() {
                "global" => SiteSet::Global,
                "colocated" | "co-located" => SiteSet::Colocated,
                other => bail!("unknown site preset {other:?}"),
            },
            Some(Json::Arr(items)) => {
                let mut out = Vec::new();
                for (k, item) in items.iter().enumerate() {
                    let name = item
                        .get("name")
                        .and_then(|v| v.as_str())
                        .map(str::to_string)
                        .unwrap_or_else(|| format!("site{k}"));
                    let lat = req_f64(item, "latitude")?;
                    let utc = item.get("utc_offset_h").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    let cl = item.get("cloudiness").and_then(|v| v.as_f64()).unwrap_or(0.35);
                    if !(-90.0..=90.0).contains(&lat) {
                        bail!("site {name}: latitude {lat} out of range");
                    }
                    if !(0.0..=1.0).contains(&cl) {
                        bail!("site {name}: cloudiness {cl} out of [0,1]");
                    }
                    out.push(Site { name, latitude: lat, utc_offset_h: utc, cloudiness: cl });
                }
                SiteSet::Custom(out)
            }
            Some(other) => bail!("sites must be a preset name or an array, got {other:?}"),
        };
        spec.regional_clouds = match j.get("regional_clouds") {
            None => sites.default_regional_clouds(),
            Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64().ok_or_else(|| anyhow!("regional_clouds must be a number or null"))?,
            ),
        };
        spec.sites = sites;
        if let Some(v) = j.get("start_day_of_year") {
            let day = v
                .as_f64()
                .ok_or_else(|| anyhow!("start_day_of_year must be a number"))?;
            if !(1.0..=366.0).contains(&day) {
                bail!("start_day_of_year {day} out of 1..=366");
            }
            spec.start_day_of_year = Some(day as u32);
        }
        if let Some(v) = j.get("capacity_w") {
            spec.capacity_w = num_or_list(v, "capacity_w")?;
        }
        if let Some(v) = j.get("battery_wh") {
            spec.battery_wh = num_or_list(v, "battery_wh")?;
        }
        if let Some(v) = j.get("battery_sustain_frac").and_then(|v| v.as_f64()) {
            spec.battery_sustain_frac = v;
        }
        if let Some(v) = j.get("device_mix") {
            let items = v.as_arr().ok_or_else(|| anyhow!("device_mix must be an array"))?;
            if items.len() != 3 {
                bail!("device_mix needs exactly 3 weights [small, mid, large]");
            }
            let mut mix = [0.0; 3];
            for (k, item) in items.iter().enumerate() {
                mix[k] = item.as_f64().ok_or_else(|| anyhow!("device_mix entries must be numbers"))?;
            }
            spec.device_mix = Some(mix);
        }
        if let Some(v) = j.get("energy_error_params") {
            spec.energy_error_params = Some(ErrorParams {
                sigma0: v.get("sigma0").and_then(|x| x.as_f64()).unwrap_or(0.10),
                sigma_max: v.get("sigma_max").and_then(|x| x.as_f64()).unwrap_or(0.35),
                bias: v.get("bias").and_then(|x| x.as_f64()).unwrap_or(0.02),
            });
        }
        if let Some(v) = j.get("churn") {
            spec.churn = Some(ChurnSpec::from_json(v)?);
        }
        if let Some(v) = j.get("chaos") {
            spec.chaos = Some(ChaosSpec::from_json(v)?);
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Deterministic memoization key over every trace-shaping field (the
    /// campaign runner builds one environment per distinct key+seed and
    /// shares it immutably across cells). `chaos` is deliberately NOT part
    /// of the key: fault injection happens at simulation time and leaves
    /// the built environment untouched, so cells that differ only in
    /// chaos must share one build.
    pub fn cache_key(&self) -> String {
        use std::fmt::Write as _;
        let mut k = String::new();
        match &self.sites {
            SiteSet::Global => k.push_str("sites=global"),
            SiteSet::Colocated => k.push_str("sites=colocated"),
            SiteSet::Custom(sites) => {
                k.push_str("sites=[");
                for s in sites {
                    let _ = write!(
                        k,
                        "({},{:?},{:?},{:?})",
                        s.name, s.latitude, s.utc_offset_h, s.cloudiness
                    );
                }
                k.push(']');
            }
        }
        let _ = write!(
            k,
            ";day={:?};reg={:?};cap={:?};bat={:?};sus={:?};mix={:?}",
            self.start_day_of_year,
            self.regional_clouds,
            self.capacity_w,
            self.battery_wh,
            self.battery_sustain_frac,
            self.device_mix,
        );
        if let Some(e) = self.energy_error_params {
            let _ = write!(k, ";err=({:?},{:?},{:?})", e.sigma0, e.sigma_max, e.bias);
        }
        if let Some(c) = &self.churn {
            let _ = write!(k, ";churn=({:?},{:?})", c.outages_per_day, c.mean_outage_min);
        }
        k
    }
}

/// Per-run knobs that combine with an [`EnvSpec`] into one environment —
/// the fields of the legacy `config::ScenarioConfig` that are not shape.
#[derive(Clone, Copy, Debug)]
pub struct EnvConfig {
    pub n_clients: usize,
    pub days: usize,
    pub step_minutes: f64,
    pub energy_error: ErrorLevel,
    pub load_error: ErrorLevel,
    /// give this domain unlimited energy + its clients unlimited capacity
    pub unlimited_domain: Option<usize>,
    pub seed: u64,
}

impl Default for EnvConfig {
    fn default() -> Self {
        EnvConfig {
            n_clients: 100,
            days: 7,
            step_minutes: 1.0,
            energy_error: ErrorLevel::Realistic,
            load_error: ErrorLevel::Realistic,
            unlimited_domain: None,
            seed: 0,
        }
    }
}

/// Parse an [`ErrorLevel`] axis value.
pub fn parse_error_level(s: &str) -> Result<ErrorLevel> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "perfect" => ErrorLevel::Perfect,
        "realistic" => ErrorLevel::Realistic,
        "unavailable" | "none" => ErrorLevel::Unavailable,
        other => bail!("unknown error level {other:?}"),
    })
}

pub fn error_level_name(e: ErrorLevel) -> &'static str {
    match e {
        ErrorLevel::Perfect => "perfect",
        ErrorLevel::Realistic => "realistic",
        ErrorLevel::Unavailable => "unavailable",
    }
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("missing numeric field {key:?}"))
}

/// A scalar broadcasts; an array is taken verbatim.
fn num_or_list(j: &Json, key: &str) -> Result<Vec<f64>> {
    match j {
        Json::Num(x) => Ok(vec![*x]),
        Json::Arr(items) => items
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("{key} entries must be numbers")))
            .collect(),
        other => bail!("{key} must be a number or an array, got {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_specs_match_legacy_defaults() {
        let g = EnvSpec::global();
        assert!(matches!(g.sites, SiteSet::Global));
        assert_eq!(g.start_day(), 159);
        assert!(g.regional_clouds.is_none());
        assert_eq!(g.capacity_of(7), 800.0);
        assert_eq!(g.battery_of(7), 0.0);
        let c = EnvSpec::colocated();
        assert_eq!(c.start_day(), 196);
        assert_eq!(c.regional_clouds, Some(0.4));
        c.validate().unwrap();
    }

    #[test]
    fn parses_full_spec() {
        let text = r#"{
            "sites": [
                {"name": "Reykjavik", "latitude": 64.1, "utc_offset_h": 0.0, "cloudiness": 0.5},
                {"name": "Atacama", "latitude": -24.5, "utc_offset_h": -4.0, "cloudiness": 0.05}
            ],
            "start_day_of_year": 80,
            "capacity_w": [500, 1200],
            "battery_wh": 400,
            "device_mix": [0.7, 0.2, 0.1],
            "energy_error_params": {"sigma0": 0.2, "bias": -0.05},
            "churn": {"outages_per_day": 1.5, "mean_outage_min": 45}
        }"#;
        let spec = EnvSpec::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.sites.sites().len(), 2);
        assert_eq!(spec.start_day(), 80);
        assert_eq!(spec.capacity_of(1), 1200.0);
        assert_eq!(spec.battery_of(0), 400.0);
        assert_eq!(spec.battery_of(1), 400.0);
        assert_eq!(spec.device_mix.unwrap()[0], 0.7);
        let e = spec.energy_error_params.unwrap();
        assert_eq!(e.sigma0, 0.2);
        assert_eq!(e.sigma_max, 0.35); // default kept
        assert!(spec.churn.is_some());
    }

    #[test]
    fn preset_strings_and_defaults() {
        let j = Json::parse(r#"{"sites": "colocated"}"#).unwrap();
        let spec = EnvSpec::from_json(&j).unwrap();
        assert!(matches!(spec.sites, SiteSet::Colocated));
        // colocated preset implies the shared regional cloud process
        assert_eq!(spec.regional_clouds, Some(0.4));
        // explicit null disables it
        let j = Json::parse(r#"{"sites": "colocated", "regional_clouds": null}"#).unwrap();
        assert!(EnvSpec::from_json(&j).unwrap().regional_clouds.is_none());
    }

    #[test]
    fn rejects_bad_specs() {
        for text in [
            r#"{"sites": "mars"}"#,
            r#"{"sites": [{"latitude": 200}]}"#,
            r#"{"capacity_w": [1, 2, 3]}"#,       // 3 entries for 10 domains
            r#"{"device_mix": [1.0, 2.0]}"#,      // wrong arity
            r#"{"device_mix": [-1.0, 1.0, 1.0]}"#, // negative weight
            r#"{"start_day_of_year": null}"#,     // must be numeric
            r#"{"start_day_of_year": 400}"#,      // out of range
        ] {
            assert!(
                EnvSpec::from_json(&Json::parse(text).unwrap()).is_err(),
                "accepted {text}"
            );
        }
    }

    #[test]
    fn cache_keys_distinguish_specs() {
        let a = EnvSpec::global();
        let b = EnvSpec::colocated();
        let mut c = EnvSpec::global();
        c.battery_wh = vec![500.0];
        assert_ne!(a.cache_key(), b.cache_key());
        assert_ne!(a.cache_key(), c.cache_key());
        assert_eq!(a.cache_key(), EnvSpec::global().cache_key());
    }

    #[test]
    fn chaos_parses_but_does_not_split_the_build_cache() {
        let j = Json::parse(r#"{"chaos": {"dropout_per_round": 0.3}}"#).unwrap();
        let spec = EnvSpec::from_json(&j).unwrap();
        let chaos = spec.chaos.expect("chaos key should parse");
        assert_eq!(chaos.dropout_per_round, 0.3);
        assert_eq!(chaos.stale_prob, ChaosSpec::default().stale_prob);
        // sim-time knob: same environment build → same cache key
        assert_eq!(spec.cache_key(), EnvSpec::global().cache_key());
        // invalid chaos is rejected at parse time
        let j = Json::parse(r#"{"chaos": {"slow_factor": 2.0}}"#).unwrap();
        assert!(EnvSpec::from_json(&j).is_err());
    }
}
