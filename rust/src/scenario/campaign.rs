//! Campaign runner: expand a declarative spec grid into
//! scenario×strategy×seed cells and drain them across workers.
//!
//! ## Campaign JSON schema
//!
//! ```json
//! {
//!   "name": "robustness-sweep",
//!   "preset": "tiny", "days": 1, "clients": 20, "n_per_round": 4,
//!   "d_max": 30, "eval_every": 5, "dataset_scale": 0.2,
//!   "target_accuracy": 0.5,
//!   "envs": ["global", "colocated", {"name": "islands", "sites": [...]}],
//!   "alpha": [0.1, 0.5, 1.0],
//!   "energy_error": ["perfect", "realistic"],
//!   "load_error": ["realistic"],
//!   "battery_wh_axis": [0, 500],
//!   "churn_axis": [null, {"outages_per_day": 2, "mean_outage_min": 45}],
//!   "chaos_axis": [null, {"dropout_per_round": 0.2, "stale_prob": 0.1}],
//!   "strategies": ["FedZero", "Random", "Oort-1.3n"],
//!   "seeds": [0, 1, 2]
//! }
//! ```
//!
//! Every axis is optional. `envs` entries are preset names or full
//! [`EnvSpec`] objects (with an optional `"name"`); `battery_wh_axis`,
//! `churn_axis` and `chaos_axis`, when present, override the envs' own
//! knobs cell by cell. The grid is the cartesian product expanded in the
//! FIXED nested order env → alpha → energy_error → load_error →
//! battery → churn → chaos → seed → strategy, so cell indices (and the
//! report) are stable across machines and worker counts. Chaos is a
//! sim-time knob (see [`crate::sim::chaos`]): cells differing only in
//! chaos still share one memoised environment build.
//!
//! ## Determinism
//!
//! Cells are drained by the shared work-stealing scheduler
//! (`util::par::steal`) over `workers` threads (1 = inline), but every
//! cell is a pure function of (spec, cell axes)
//! — mock backend, seeded RNG, bit-identical parallel sim paths — and
//! results are stored by cell index, so `report_json()` is
//! **byte-identical for any worker count** (gated by
//! `tests/integration_campaign.rs` at 1/2/8 workers). Wall-clock
//! numbers live only in [`CampaignRun`], never in the report.
//!
//! ## Trace + dataset memoization
//!
//! Cells differing only in strategy share one environment build: the
//! runner keys [`crate::scenario::build_env`] outputs by
//! (env cache key, alpha, errors, seed, run shape) and hands each cell
//! a clone of the shared immutable build — regenerating a 7-day solar +
//! load trace set per strategy would otherwise dominate small-model
//! campaigns. The synthetic dataset partition is memoized separately
//! (per preset/seed/α/clients/scale — it is env-axis-blind, so env
//! cells share it even when their trace builds miss). Both caches use
//! the same `Arc` + build-outside-the-lock pattern; hit/miss counts for
//! both are reported by `benches/campaign.rs`.
//!
//! ## Cost-ordered drain
//!
//! Per-cell wall-clock varies ~10x across a grid (exact solver vs
//! random baseline, churn/chaos on vs off). The parallel drain seeds
//! the scheduler longest-first by a static cost model
//! ([`CampaignCell::cost`]: days × clients × d_max, scaled by strategy
//! class and churn/chaos presence) so the heavy prefix spreads across
//! the seed ranges, and work stealing covers what the static model
//! can't predict: a worker that finishes its range steals queued cells
//! from a worker stuck on a monster one. Results are still stored by
//! cell index, so the report stays byte-identical at any worker count —
//! the schedule changes *when* a cell runs, never what it computes.
//!
//! ## Durable resume
//!
//! [`run_campaign_durable`] additionally persists one completion record
//! per finished cell (`<dir>/cells/cell_<index>.json`, written
//! atomically) carrying the full deterministic [`CellResult`] plus a
//! fingerprint of the campaign identity (run shape + every cell label).
//! A later invocation over the same directory reloads matching records
//! and re-runs only the missing/stale cells — the final report is
//! byte-identical to an uninterrupted run at any worker count, because
//! every cell is a pure function of (spec, cell axes) and the record
//! round-trips its numbers exactly (integers and shortest-roundtrip
//! floats through `util::json`). Records from a different grid, a
//! different schema version, or a torn write fail the match and are
//! simply recomputed.
//!
//! Cell simulations always run with the chaos `crash_prob` knob
//! disarmed: a coordinator death is a process-level fault handled by
//! THIS resume layer (and per-run by `Simulation::resume_from`), not a
//! per-cell outcome — a cell that deterministically re-crashed on every
//! resume attempt would livelock the campaign forever.

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{
    build_mock_env_with, build_mock_partition, preset_uses_alpha, run_built_mock,
    ExperimentSpec, RunReport, StrategyKind,
};
use crate::data::Partition;
use crate::trace::forecast::ErrorLevel;
use crate::util::fsx;
use crate::util::json::{arr, num, obj, parse_u64_hex, s, u64_hex, Json};
use crate::util::obs;
use crate::util::par;
use crate::util::stats;

use super::churn::ChurnSpec;
use super::spec::{error_level_name, parse_error_level, EnvSpec};
use crate::sim::ChaosSpec;

/// One sweep definition: base experiment shape + grid axes.
#[derive(Clone, Debug)]
pub struct CampaignSpec {
    pub name: String,
    pub preset: String,
    pub days: usize,
    pub n_clients: usize,
    pub n_per_round: usize,
    pub d_max: usize,
    pub eval_every: usize,
    pub dataset_scale: f64,
    /// absolute accuracy target for the time/energy-to-accuracy columns
    pub target_accuracy: f64,
    // --- axes (expansion order is fixed; see the module docs) ---
    pub envs: Vec<(String, EnvSpec)>,
    pub alphas: Vec<f64>,
    pub energy_errors: Vec<ErrorLevel>,
    pub load_errors: Vec<ErrorLevel>,
    /// empty = each env keeps its own battery knob
    pub battery_axis: Vec<f64>,
    /// empty = each env keeps its own churn knob; `None` entry = no churn
    pub churn_axis: Vec<Option<ChurnSpec>>,
    /// empty = each env keeps its own chaos knob; `None` entry = no faults
    pub chaos_axis: Vec<Option<ChaosSpec>>,
    pub seeds: Vec<u64>,
    pub strategies: Vec<StrategyKind>,
}

impl CampaignSpec {
    /// A minimal 2-cell smoke campaign (one env, FedZero vs Random) —
    /// the CI gate and the determinism fixtures build on this.
    pub fn smoke() -> CampaignSpec {
        CampaignSpec {
            name: "smoke".into(),
            preset: "tiny".into(),
            days: 1,
            n_clients: 20,
            n_per_round: 4,
            d_max: 30,
            eval_every: 5,
            dataset_scale: 0.2,
            target_accuracy: 0.3,
            envs: vec![("global".into(), EnvSpec::global())],
            alphas: vec![0.5],
            energy_errors: vec![ErrorLevel::Realistic],
            load_errors: vec![ErrorLevel::Realistic],
            battery_axis: Vec::new(),
            churn_axis: Vec::new(),
            chaos_axis: Vec::new(),
            seeds: vec![0],
            strategies: vec![StrategyKind::FedZero, StrategyKind::Random],
        }
    }

    pub fn from_json(j: &Json) -> Result<CampaignSpec> {
        let mut spec = CampaignSpec::smoke();
        spec.name = j.get("name").and_then(|v| v.as_str()).unwrap_or("campaign").to_string();
        if let Some(v) = j.get("preset").and_then(|v| v.as_str()) {
            spec.preset = v.to_string();
        }
        spec.days = j.get("days").and_then(|v| v.as_usize()).unwrap_or(spec.days);
        spec.n_clients = j.get("clients").and_then(|v| v.as_usize()).unwrap_or(spec.n_clients);
        spec.n_per_round =
            j.get("n_per_round").and_then(|v| v.as_usize()).unwrap_or(spec.n_per_round);
        spec.d_max = j.get("d_max").and_then(|v| v.as_usize()).unwrap_or(spec.d_max);
        spec.eval_every =
            j.get("eval_every").and_then(|v| v.as_usize()).unwrap_or(spec.eval_every);
        spec.dataset_scale =
            j.get("dataset_scale").and_then(|v| v.as_f64()).unwrap_or(spec.dataset_scale);
        spec.target_accuracy =
            j.get("target_accuracy").and_then(|v| v.as_f64()).unwrap_or(spec.target_accuracy);
        if let Some(items) = j.get("envs").and_then(|v| v.as_arr()) {
            let mut envs = Vec::new();
            for (k, item) in items.iter().enumerate() {
                match item {
                    Json::Str(name) => match name.as_str() {
                        "global" => envs.push(("global".to_string(), EnvSpec::global())),
                        "colocated" | "co-located" => {
                            envs.push(("colocated".to_string(), EnvSpec::colocated()))
                        }
                        other => bail!("unknown env preset {other:?}"),
                    },
                    Json::Obj(_) => {
                        let name = item
                            .get("name")
                            .and_then(|v| v.as_str())
                            .map(str::to_string)
                            .unwrap_or_else(|| format!("env{k}"));
                        envs.push((name, EnvSpec::from_json(item)?));
                    }
                    other => bail!("envs entries must be names or objects, got {other:?}"),
                }
            }
            if envs.is_empty() {
                bail!("envs must not be empty");
            }
            spec.envs = envs;
        }
        if let Some(items) = j.get("alpha").and_then(|v| v.as_arr()) {
            spec.alphas = items
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| anyhow!("alpha entries must be numbers")))
                .collect::<Result<_>>()?;
        }
        for (key, out) in [
            ("energy_error", &mut spec.energy_errors),
            ("load_error", &mut spec.load_errors),
        ] {
            if let Some(items) = j.get(key).and_then(|v| v.as_arr()) {
                *out = items
                    .iter()
                    .map(|v| {
                        parse_error_level(
                            v.as_str().ok_or_else(|| anyhow!("{key} entries must be strings"))?,
                        )
                    })
                    .collect::<Result<_>>()?;
            }
        }
        if let Some(items) = j.get("battery_wh_axis").and_then(|v| v.as_arr()) {
            spec.battery_axis = items
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| anyhow!("battery_wh_axis must be numeric")))
                .collect::<Result<_>>()?;
        }
        if let Some(items) = j.get("churn_axis").and_then(|v| v.as_arr()) {
            spec.churn_axis = items
                .iter()
                .map(|v| match v {
                    Json::Null => Ok(None),
                    other => ChurnSpec::from_json(other).map(Some),
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = j.get("chaos_axis").and_then(|v| v.as_arr()) {
            spec.chaos_axis = items
                .iter()
                .map(|v| match v {
                    Json::Null => Ok(None),
                    other => ChaosSpec::from_json(other).map(Some),
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = j.get("seeds").and_then(|v| v.as_arr()) {
            spec.seeds = items
                .iter()
                .map(|v| {
                    v.as_f64()
                        .map(|x| x as u64)
                        .ok_or_else(|| anyhow!("seeds must be numeric"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(items) = j.get("strategies").and_then(|v| v.as_arr()) {
            spec.strategies = items
                .iter()
                .map(|v| {
                    StrategyKind::parse(
                        v.as_str().ok_or_else(|| anyhow!("strategies must be strings"))?,
                    )
                })
                .collect::<Result<_>>()?;
        }
        for (name, len) in [
            ("alpha", spec.alphas.len()),
            ("energy_error", spec.energy_errors.len()),
            ("load_error", spec.load_errors.len()),
            ("seeds", spec.seeds.len()),
            ("strategies", spec.strategies.len()),
        ] {
            if len == 0 {
                bail!("axis {name} must not be empty");
            }
        }
        Ok(spec)
    }

    /// Expand the grid in the documented fixed nesting order.
    pub fn expand(&self) -> Vec<CampaignCell> {
        let mut cells = Vec::new();
        let batteries: Vec<Option<f64>> = if self.battery_axis.is_empty() {
            vec![None]
        } else {
            self.battery_axis.iter().map(|&b| Some(b)).collect()
        };
        let churns: Vec<Option<Option<ChurnSpec>>> = if self.churn_axis.is_empty() {
            vec![None]
        } else {
            self.churn_axis.iter().map(|c| Some(*c)).collect()
        };
        let chaoses: Vec<Option<Option<ChaosSpec>>> = if self.chaos_axis.is_empty() {
            vec![None]
        } else {
            self.chaos_axis.iter().map(|c| Some(*c)).collect()
        };
        for (env_name, env) in &self.envs {
            for &alpha in &self.alphas {
                for &ee in &self.energy_errors {
                    for &le in &self.load_errors {
                        for battery in &batteries {
                            for churn in &churns {
                                for chaos in &chaoses {
                                    for &seed in &self.seeds {
                                        for &strategy in &self.strategies {
                                            let mut env = env.clone();
                                            if let Some(b) = battery {
                                                env.battery_wh =
                                                    if *b > 0.0 { vec![*b] } else { Vec::new() };
                                            }
                                            if let Some(c) = churn {
                                                env.churn = *c;
                                            }
                                            if let Some(c) = chaos {
                                                env.chaos = *c;
                                            }
                                            let label = format!(
                                                "{env_name}/a{alpha}/ee-{}/le-{}/bat{}/churn{}/chaos{}/s{seed}/{}",
                                                error_level_name(ee),
                                                error_level_name(le),
                                                env.battery_of(0),
                                                env.churn.is_some() as u8,
                                                env.chaos.is_some() as u8,
                                                strategy.name(),
                                            );
                                            cells.push(CampaignCell {
                                                index: cells.len(),
                                                label,
                                                env_name: env_name.clone(),
                                                env,
                                                alpha,
                                                energy_error: ee,
                                                load_error: le,
                                                seed,
                                                strategy,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }
}

/// One fully resolved grid point.
#[derive(Clone, Debug)]
pub struct CampaignCell {
    pub index: usize,
    pub label: String,
    pub env_name: String,
    pub env: EnvSpec,
    pub alpha: f64,
    pub energy_error: ErrorLevel,
    pub load_error: ErrorLevel,
    pub seed: u64,
    pub strategy: StrategyKind,
}

impl CampaignCell {
    /// The coordinator experiment this cell runs (always mock-backed:
    /// campaigns are simulation sweeps, not PJRT training runs).
    pub fn experiment(&self, spec: &CampaignSpec) -> ExperimentSpec {
        ExperimentSpec {
            preset: spec.preset.clone(),
            strategy: self.strategy,
            days: spec.days,
            n_clients: spec.n_clients,
            n_per_round: spec.n_per_round,
            d_max: spec.d_max,
            seed: self.seed,
            energy_error: self.energy_error,
            load_error: self.load_error,
            dataset_scale: spec.dataset_scale,
            use_mock: true,
            eval_every: spec.eval_every,
            eval_subset: 0,
            partition_alpha: Some(self.alpha),
            env: Some(self.env.clone()),
            ..Default::default()
        }
    }

    /// Static drain-scheduling cost estimate (arbitrary units; only the
    /// ORDER matters — see the module docs). Base is the sim volume
    /// days × clients × d_max, scaled up for solver-heavy strategy
    /// classes and for churn/chaos cells (event translation + fault
    /// plans per round). Deterministic per cell, so the longest-first
    /// order is identical on every run and worker count.
    pub fn cost(&self, spec: &CampaignSpec) -> u64 {
        let base = (spec.days.max(1) as u64)
            * (spec.n_clients.max(1) as u64)
            * (spec.d_max.max(1) as u64);
        let strategy = match self.strategy {
            StrategyKind::FedZeroExact => 8,
            StrategyKind::FedZero
            | StrategyKind::FedZeroCa
            | StrategyKind::SemiSync
            | StrategyKind::SemiSyncCa => 4,
            _ => 1,
        };
        let mut cost = base * strategy;
        if self.env.churn.is_some() {
            cost *= 2;
        }
        if self.env.chaos.is_some() {
            cost *= 2;
        }
        cost
    }
}

/// Deterministic summary of one finished cell (everything that goes
/// into the report; no wall-clock values).
#[derive(Clone, Debug)]
pub struct CellResult {
    pub cell: CampaignCell,
    pub rounds: usize,
    pub best_accuracy: f64,
    pub final_accuracy: f64,
    pub time_to_target_days: Option<f64>,
    pub energy_to_target_kwh: Option<f64>,
    pub energy_kwh: f64,
    pub wasted_kwh: f64,
    pub mean_round_min: f64,
    pub fairness_domain_std: f64,
    pub fairness_jain: f64,
    pub train_steps: u64,
    /// epoch-fenced stale submissions rejected by the round FSM
    pub rejected_updates: usize,
    /// rounds closed by their deadline's `Timeout` event
    pub timeout_rounds: usize,
}

impl CellResult {
    fn from_report(cell: &CampaignCell, target: f64, report: &RunReport) -> CellResult {
        let m = &report.metrics;
        let shares = m.participation_shares(report.client_domains.len());
        let (_, between_std) =
            m.participation_by_domain(&report.client_domains, report.n_domains);
        CellResult {
            cell: cell.clone(),
            rounds: m.rounds.len(),
            best_accuracy: m.best_accuracy(),
            final_accuracy: m.final_accuracy(),
            time_to_target_days: m.time_to_accuracy(target),
            energy_to_target_kwh: m.energy_to_accuracy(target),
            energy_kwh: m.total_energy_kwh(),
            wasted_kwh: m.total_wasted_kwh(),
            mean_round_min: m.mean_round_duration_min(),
            fairness_domain_std: between_std,
            fairness_jain: stats::jain(&shares),
            train_steps: report.steps_executed,
            rejected_updates: m.rejected_updates,
            timeout_rounds: m.timeout_rounds(),
        }
    }

    fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        obj(vec![
            ("cell", num(self.cell.index as f64)),
            ("label", s(&self.cell.label)),
            ("env", s(&self.cell.env_name)),
            ("alpha", num(self.cell.alpha)),
            ("energy_error", s(error_level_name(self.cell.energy_error))),
            ("load_error", s(error_level_name(self.cell.load_error))),
            ("battery_wh", num(self.cell.env.battery_of(0))),
            ("churn", Json::Bool(self.cell.env.churn.is_some())),
            ("chaos", Json::Bool(self.cell.env.chaos.is_some())),
            ("seed", num(self.cell.seed as f64)),
            ("strategy", s(self.cell.strategy.name())),
            ("rounds", num(self.rounds as f64)),
            ("best_accuracy", num(self.best_accuracy)),
            ("final_accuracy", num(self.final_accuracy)),
            ("time_to_target_days", opt(self.time_to_target_days)),
            ("energy_to_target_kwh", opt(self.energy_to_target_kwh)),
            ("energy_kwh", num(self.energy_kwh)),
            ("wasted_kwh", num(self.wasted_kwh)),
            ("mean_round_min", num(self.mean_round_min)),
            ("fairness_domain_std", num(self.fairness_domain_std)),
            ("fairness_jain", num(self.fairness_jain)),
            ("train_steps", num(self.train_steps as f64)),
            ("rejected_updates", num(self.rejected_updates as f64)),
            ("timeout_rounds", num(self.timeout_rounds as f64)),
        ])
    }

    /// Durable completion record for campaign resume: every report
    /// number, plus the campaign fingerprint and the cell identity so a
    /// resume can refuse records from a different grid.
    fn to_record_json(&self, fingerprint: u64) -> Json {
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        obj(vec![
            ("version", s(CELL_RECORD_VERSION)),
            ("fingerprint", u64_hex(fingerprint)),
            ("cell", num(self.cell.index as f64)),
            ("label", s(&self.cell.label)),
            ("rounds", num(self.rounds as f64)),
            ("best_accuracy", num(self.best_accuracy)),
            ("final_accuracy", num(self.final_accuracy)),
            ("time_to_target_days", opt(self.time_to_target_days)),
            ("energy_to_target_kwh", opt(self.energy_to_target_kwh)),
            ("energy_kwh", num(self.energy_kwh)),
            ("wasted_kwh", num(self.wasted_kwh)),
            ("mean_round_min", num(self.mean_round_min)),
            ("fairness_domain_std", num(self.fairness_domain_std)),
            ("fairness_jain", num(self.fairness_jain)),
            ("train_steps", u64_hex(self.train_steps)),
            ("rejected_updates", num(self.rejected_updates as f64)),
            ("timeout_rounds", num(self.timeout_rounds as f64)),
        ])
    }

    /// Accept a completion record iff its version, fingerprint and cell
    /// identity all match this expansion — anything else (older schema,
    /// different grid, torn write, index/label drift) returns `None`
    /// and the cell is recomputed.
    fn from_record_json(
        j: &Json,
        cell: &CampaignCell,
        fingerprint: u64,
    ) -> Option<CellResult> {
        if j.get("version").and_then(|v| v.as_str()) != Some(CELL_RECORD_VERSION) {
            return None;
        }
        if parse_u64_hex(j.get("fingerprint")?).ok()? != fingerprint {
            return None;
        }
        if j.get("cell").and_then(|v| v.as_usize()) != Some(cell.index) {
            return None;
        }
        if j.get("label").and_then(|v| v.as_str()) != Some(cell.label.as_str()) {
            return None;
        }
        let f = |k: &str| j.get(k).and_then(|v| v.as_f64());
        let o = |k: &str| match j.get(k) {
            Some(Json::Null) | None => Some(None),
            Some(v) => v.as_f64().map(Some),
        };
        Some(CellResult {
            cell: cell.clone(),
            rounds: j.get("rounds").and_then(|v| v.as_usize())?,
            best_accuracy: f("best_accuracy")?,
            final_accuracy: f("final_accuracy")?,
            time_to_target_days: o("time_to_target_days")?,
            energy_to_target_kwh: o("energy_to_target_kwh")?,
            energy_kwh: f("energy_kwh")?,
            wasted_kwh: f("wasted_kwh")?,
            mean_round_min: f("mean_round_min")?,
            fairness_domain_std: f("fairness_domain_std")?,
            fairness_jain: f("fairness_jain")?,
            train_steps: parse_u64_hex(j.get("train_steps")?).ok()?,
            rejected_updates: j.get("rejected_updates").and_then(|v| v.as_usize())?,
            timeout_rounds: j.get("timeout_rounds").and_then(|v| v.as_usize())?,
        })
    }
}

/// Completion-record schema tag; bumped with [`CellResult`] layout
/// changes so a resume never misreads an old record.
const CELL_RECORD_VERSION: &str = "fedzero-campaign-cell-v1";

fn fnv1a64(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// 64-bit FNV-1a over the campaign identity: the run shape plus every
/// expanded cell label in index order. Two specs that could produce
/// different cell results never share a fingerprint (labels encode the
/// full axis assignment; the shape covers the sim volume knobs).
fn spec_fingerprint(spec: &CampaignSpec, cells: &[CampaignCell]) -> u64 {
    let shape = format!(
        "{}|{}|{}|{}|{}|{}|{}|{:?}|{:?}",
        spec.name,
        spec.preset,
        spec.days,
        spec.n_clients,
        spec.n_per_round,
        spec.d_max,
        spec.eval_every,
        spec.dataset_scale,
        spec.target_accuracy,
    );
    let mut h = fnv1a64(0xcbf2_9ce4_8422_2325, shape.as_bytes());
    for c in cells {
        h = fnv1a64(h, c.label.as_bytes());
        h = fnv1a64(h, b"\x00");
    }
    h
}

/// Atomically persist one finished cell's completion record.
fn write_cell_record(cell_dir: &Path, r: &CellResult, fingerprint: u64) -> Result<()> {
    fsx::write_atomic(
        &cell_dir.join(format!("cell_{}.json", r.cell.index)),
        r.to_record_json(fingerprint).to_string_pretty().as_bytes(),
    )
}

/// A finished campaign: ordered cell results plus runner statistics
/// (the wall-clock and memoization numbers stay OUT of the report).
pub struct CampaignRun {
    pub spec: CampaignSpec,
    pub results: Vec<CellResult>,
    pub memo_hits: usize,
    pub memo_misses: usize,
    /// synthetic-dataset partition cache hits/misses (separate from the
    /// environment cache: the partition is env-axis-blind)
    pub dataset_hits: usize,
    pub dataset_misses: usize,
    pub wall_s: f64,
}

impl CampaignRun {
    /// The deterministic machine-readable report (CAMPAIGN_report.json).
    pub fn report_json(&self) -> Json {
        obj(vec![
            ("campaign", s(&self.spec.name)),
            ("preset", s(&self.spec.preset)),
            ("days", num(self.spec.days as f64)),
            ("clients", num(self.spec.n_clients as f64)),
            ("n_per_round", num(self.spec.n_per_round as f64)),
            ("d_max", num(self.spec.d_max as f64)),
            ("target_accuracy", num(self.spec.target_accuracy)),
            ("n_cells", num(self.results.len() as f64)),
            ("cells", arr(self.results.iter().map(|r| r.to_json()).collect())),
        ])
    }

    /// Memoization hit rate over all environment lookups.
    pub fn memo_hit_rate(&self) -> f64 {
        let total = self.memo_hits + self.memo_misses;
        if total == 0 {
            0.0
        } else {
            self.memo_hits as f64 / total as f64
        }
    }

    /// Memoization hit rate over all dataset-partition lookups.
    pub fn dataset_hit_rate(&self) -> f64 {
        let total = self.dataset_hits + self.dataset_misses;
        if total == 0 {
            0.0
        } else {
            self.dataset_hits as f64 / total as f64
        }
    }
}

/// Shared immutable memo cache (see the module docs) — one instance
/// caches [`crate::config::BuiltScenario`] environment builds, another
/// the synthetic dataset [`Partition`]s.
struct MemoCache<T> {
    map: Mutex<HashMap<String, Arc<T>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl<T> MemoCache<T> {
    fn new() -> Self {
        MemoCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    fn get_or_build(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<T>,
    ) -> Result<Arc<T>> {
        if let Some(hit) = self.map.lock().unwrap().get(key).cloned() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(hit);
        }
        // build OUTSIDE the lock: concurrent workers may race to build
        // the same key (identical results; one insert wins), which beats
        // serialising every trace generation behind one mutex
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(build()?);
        let mut map = self.map.lock().unwrap();
        Ok(map.entry(key.to_string()).or_insert(built).clone())
    }
}

type EnvCache = MemoCache<crate::config::BuiltScenario>;
type DatasetCache = MemoCache<Partition>;

/// Run one cell: (memoized) dataset partition, (memoized) environment
/// build over it through the coordinator's shared mock fixture, mock
/// simulation, deterministic summary.
fn run_cell(
    spec: &CampaignSpec,
    cell: &CampaignCell,
    envs: &EnvCache,
    datasets: &DatasetCache,
) -> Result<CellResult> {
    let mut xspec = cell.experiment(spec);
    // coordinator crashes are a process-level fault handled by the
    // campaign resume layer (module docs) — an armed crash_prob would
    // deterministically kill the same cell on every resume attempt
    if let Some(env) = xspec.env.as_mut() {
        if let Some(chaos) = env.chaos.as_mut() {
            chaos.crash_prob = 0.0;
        }
    }
    // the partition is env-axis-blind: key it by the dataset inputs only
    // so env/error cells share one synthetic dataset generation
    let ds_key = format!(
        "preset={}|seed={}|alpha={:?}|nc={}|scale={:?}",
        spec.preset, cell.seed, cell.alpha, spec.n_clients, spec.dataset_scale,
    );
    let partition = datasets
        .get_or_build(&ds_key, || Ok(build_mock_partition(&xspec)))
        .with_context(|| format!("cell {} ({})", cell.index, cell.label))?;
    // key over every build input except the strategy — the axis cells
    // share builds across
    let key = format!(
        "{}|alpha={:?}|ee={}|le={}|seed={}|preset={}|nc={}|days={}|scale={:?}",
        cell.env.cache_key(),
        cell.alpha,
        error_level_name(cell.energy_error),
        error_level_name(cell.load_error),
        cell.seed,
        spec.preset,
        spec.n_clients,
        spec.days,
        spec.dataset_scale,
    );
    let built = envs
        .get_or_build(&key, || build_mock_env_with(&xspec, &partition))
        .with_context(|| format!("cell {} ({})", cell.index, cell.label))?;
    let report = run_built_mock(&xspec, (*built).clone())
        .with_context(|| format!("cell {} ({})", cell.index, cell.label))?;
    Ok(CellResult::from_report(cell, spec.target_accuracy, &report))
}

/// Expand and drain a campaign across `workers` threads (1 = inline).
/// Results are index-ordered; see the module docs for the determinism
/// and memoization contracts.
pub fn run_campaign(spec: &CampaignSpec, workers: usize) -> Result<CampaignRun> {
    run_campaign_with(spec, workers, None)
}

/// [`run_campaign`] with durable per-cell completion records under
/// `dir` — an interrupted campaign re-invoked over the same directory
/// reloads finished cells and re-runs only the rest, producing a
/// byte-identical report (module docs, "Durable resume").
pub fn run_campaign_durable(
    spec: &CampaignSpec,
    workers: usize,
    dir: &Path,
) -> Result<CampaignRun> {
    run_campaign_with(spec, workers, Some(dir))
}

fn run_campaign_with(
    spec: &CampaignSpec,
    workers: usize,
    durable: Option<&Path>,
) -> Result<CampaignRun> {
    if spec.alphas.len() > 1 && !preset_uses_alpha(&spec.preset) {
        bail!(
            "preset {:?} uses an imbalanced partition with no α knob — an \
             alpha axis of {} values would produce identical duplicate cells",
            spec.preset,
            spec.alphas.len()
        );
    }
    let cells = spec.expand();
    if cells.is_empty() {
        bail!("campaign expands to zero cells");
    }
    let n = cells.len();
    let fingerprint = spec_fingerprint(spec, &cells);
    let mut done: Vec<Option<CellResult>> = vec![None; n];
    let cell_dir = durable.map(|d| d.join("cells"));
    if let Some(cd) = &cell_dir {
        fsx::create_dir_all(cd)?;
        for c in &cells {
            // any unreadable/unparseable/mismatched record is silently
            // recomputed (and its file overwritten on completion)
            let Ok(text) = fsx::read_to_string(&cd.join(format!("cell_{}.json", c.index)))
            else {
                continue;
            };
            let Ok(doc) = Json::parse(&text) else { continue };
            done[c.index] = CellResult::from_record_json(&doc, c, fingerprint);
        }
    }
    let pending: Vec<usize> = (0..n).filter(|&i| done[i].is_none()).collect();

    let envs = EnvCache::new();
    let datasets = DatasetCache::new();
    let t0 = Instant::now();
    // run one pending cell and, in durable mode, persist its record
    // before reporting it finished — a crash right after leaves either
    // a complete record or none (the write is atomic)
    let run_one = |i: usize| -> Result<CellResult> {
        let _cell_span = obs::span("cell", obs::Hist::CellWallNs);
        obs::add(obs::Ctr::CampaignCells, 1);
        let r = run_cell(spec, &cells[i], &envs, &datasets)?;
        if let Some(cd) = &cell_dir {
            write_cell_record(cd, &r, fingerprint)?;
        }
        Ok(r)
    };
    let results: Vec<(usize, Result<CellResult>)> = if workers <= 1 {
        pending.iter().map(|&i| (i, run_one(i))).collect()
    } else {
        // longest-first drain seeded into the shared work-stealing
        // scheduler (cost model; module docs): scheduler position p
        // holds the p-th most expensive pending cell, so the per-worker
        // seed ranges split the heavy prefix evenly and an idle worker
        // steals the queued tail instead of watching a monster cell
        // finish. Results accumulate per worker tagged by cell INDEX
        // and are scattered after the join, so the report is
        // byte-identical to the serial natural-order drain at any
        // worker count.
        let mut order: Vec<usize> = pending.clone();
        order.sort_by_key(|&i| (std::cmp::Reverse(cells[i].cost(spec)), i));
        let (locals, _stats) = par::steal::steal_exec(
            order.len(),
            workers,
            |_| Vec::<(usize, Result<CellResult>)>::new(),
            |p, local| {
                let i = order[p];
                local.push((i, run_one(i)));
            },
        );
        locals.into_iter().flatten().collect()
    };
    for (i, r) in results {
        done[i] = Some(r.with_context(|| format!("cell {i} ({})", cells[i].label))?);
    }
    let mut out = Vec::with_capacity(n);
    for (i, slot) in done.into_iter().enumerate() {
        out.push(slot.ok_or_else(|| anyhow!("cell {i} was never run"))?);
    }
    // mirror the memo accounting into the telemetry layer (the caches
    // are per-campaign; the obs counters accumulate across campaigns)
    obs::add(obs::Ctr::CampaignMemoHits, envs.hits.load(Ordering::Relaxed) as u64);
    obs::add(
        obs::Ctr::CampaignMemoMisses,
        envs.misses.load(Ordering::Relaxed) as u64,
    );
    Ok(CampaignRun {
        spec: spec.clone(),
        results: out,
        memo_hits: envs.hits.load(Ordering::Relaxed),
        memo_misses: envs.misses.load(Ordering::Relaxed),
        dataset_hits: datasets.hits.load(Ordering::Relaxed),
        dataset_misses: datasets.misses.load(Ordering::Relaxed),
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_expands_to_two_cells() {
        let cells = CampaignSpec::smoke().expand();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].strategy, StrategyKind::FedZero);
        assert_eq!(cells[1].strategy, StrategyKind::Random);
        assert_eq!(cells[0].index, 0);
        assert_ne!(cells[0].label, cells[1].label);
    }

    #[test]
    fn grid_expansion_is_the_cartesian_product_in_order() {
        let mut spec = CampaignSpec::smoke();
        spec.envs = vec![
            ("global".into(), EnvSpec::global()),
            ("colocated".into(), EnvSpec::colocated()),
        ];
        spec.alphas = vec![0.1, 1.0];
        spec.battery_axis = vec![0.0, 500.0];
        spec.churn_axis =
            vec![None, Some(ChurnSpec { outages_per_day: 2.0, mean_outage_min: 30.0 })];
        spec.chaos_axis = vec![None, Some(ChaosSpec::default())];
        spec.seeds = vec![0, 1, 2];
        let cells = spec.expand();
        assert_eq!(cells.len(), 2 * 2 * 2 * 2 * 2 * 3 * 2);
        // fixed nesting: strategy is the innermost axis, env the outermost
        assert_eq!(cells[0].strategy, StrategyKind::FedZero);
        assert_eq!(cells[1].strategy, StrategyKind::Random);
        assert_eq!(cells[0].env_name, "global");
        assert_eq!(cells.last().unwrap().env_name, "colocated");
        // battery/churn/chaos overrides resolved into the cell envs
        assert_eq!(cells[0].env.battery_of(0), 0.0);
        assert!(cells[0].env.churn.is_none());
        assert!(cells[0].env.chaos.is_none());
        let last = cells.last().unwrap();
        assert_eq!(last.env.battery_of(0), 500.0);
        assert!(last.env.churn.is_some());
        assert!(last.env.chaos.is_some());
        // chaos nests between churn and seed: with 3 seeds × 2 strategies
        // inside it, consecutive 6-cell blocks alternate the chaos flag
        assert!(cells[..6].iter().all(|c| c.env.chaos.is_none()));
        assert!(cells[6..12].iter().all(|c| c.env.chaos.is_some()));
        // indices are dense and ordered
        for (k, c) in cells.iter().enumerate() {
            assert_eq!(c.index, k);
        }
    }

    #[test]
    fn campaign_json_parses_axes() {
        let text = r#"{
            "name": "sweep", "preset": "tiny", "days": 1, "clients": 16,
            "n_per_round": 3, "d_max": 20, "dataset_scale": 0.2,
            "target_accuracy": 0.4,
            "envs": ["global", {"name": "islands",
                     "sites": [{"name": "a", "latitude": 10},
                               {"name": "b", "latitude": -10}]}],
            "alpha": [0.1, 0.5],
            "energy_error": ["perfect", "realistic"],
            "battery_wh_axis": [0, 250],
            "churn_axis": [null, {"outages_per_day": 1, "mean_outage_min": 30}],
            "chaos_axis": [null, {"dropout_per_round": 0.2}],
            "strategies": ["FedZero"],
            "seeds": [7]
        }"#;
        let spec = CampaignSpec::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(spec.envs.len(), 2);
        assert_eq!(spec.envs[1].0, "islands");
        assert_eq!(spec.alphas, vec![0.1, 0.5]);
        assert_eq!(spec.energy_errors.len(), 2);
        assert_eq!(spec.battery_axis, vec![0.0, 250.0]);
        assert_eq!(spec.churn_axis.len(), 2);
        assert!(spec.churn_axis[0].is_none());
        assert_eq!(spec.chaos_axis.len(), 2);
        assert!(spec.chaos_axis[0].is_none());
        assert_eq!(spec.chaos_axis[1].unwrap().dropout_per_round, 0.2);
        assert_eq!(spec.expand().len(), 2 * 2 * 2 * 2 * 2 * 2);
        // bad specs are rejected
        assert!(CampaignSpec::from_json(&Json::parse(r#"{"strategies": []}"#).unwrap()).is_err());
        assert!(
            CampaignSpec::from_json(&Json::parse(r#"{"strategies": ["bogus"]}"#).unwrap())
                .is_err()
        );
    }

    #[test]
    fn alpha_sweep_over_imbalanced_preset_is_rejected() {
        // "seq" partitions log-normally (no α knob): sweeping α would
        // run bit-identical duplicate cells and report them as distinct
        let mut spec = CampaignSpec::smoke();
        spec.preset = "seq".into();
        spec.alphas = vec![0.1, 0.5, 1.0];
        assert!(run_campaign(&spec, 1).is_err());
        // a single (no-op) α value stays allowed
        spec.alphas = vec![0.5];
        assert_eq!(spec.expand().len(), 2);
    }

    #[test]
    fn cost_model_orders_longest_first_with_stable_ties() {
        let mut spec = CampaignSpec::smoke();
        spec.strategies = vec![
            StrategyKind::Random,       // 1x
            StrategyKind::FedZero,      // 4x
            StrategyKind::FedZeroExact, // 8x
            StrategyKind::RandomOver,   // 1x (ties with Random)
        ];
        spec.chaos_axis = vec![
            None,
            Some(ChaosSpec { dropout_per_round: 0.1, ..ChaosSpec::default() }),
        ];
        let cells = spec.expand();
        assert_eq!(cells.len(), 8);
        // chaos doubles, exact solver is the heaviest class
        let base = (spec.days.max(1) * spec.n_clients.max(1) * spec.d_max.max(1)) as u64;
        for c in &cells {
            let want = match c.strategy {
                StrategyKind::FedZeroExact => 8,
                StrategyKind::FedZero => 4,
                _ => 1,
            } * if c.env.chaos.is_some() { 2 } else { 1 };
            assert_eq!(c.cost(&spec), base * want, "cell {}", c.label);
        }
        // the drain order: longest first, index-ascending on ties —
        // a permutation of all cells
        let mut order: Vec<usize> = (0..cells.len()).collect();
        order.sort_by_key(|&i| (std::cmp::Reverse(cells[i].cost(&spec)), i));
        let costs: Vec<u64> = order.iter().map(|&i| cells[i].cost(&spec)).collect();
        assert!(costs.windows(2).all(|w| w[0] >= w[1]), "not longest-first: {costs:?}");
        for w in order.windows(2) {
            if cells[w[0]].cost(&spec) == cells[w[1]].cost(&spec) {
                assert!(w[0] < w[1], "tie broke descending: {w:?}");
            }
        }
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..cells.len()).collect::<Vec<_>>());
    }

    #[test]
    fn smoke_campaign_runs_and_reports() {
        let spec = CampaignSpec::smoke();
        let run = run_campaign(&spec, 1).unwrap();
        assert_eq!(run.results.len(), 2);
        for r in &run.results {
            assert!(r.rounds > 0, "{} did no rounds", r.cell.label);
            assert!(r.best_accuracy > 0.0);
            assert!(r.energy_kwh > 0.0);
            assert!(r.fairness_jain > 0.0 && r.fairness_jain <= 1.0 + 1e-12);
        }
        // both cells share one environment build (same env+seed, only
        // the strategy differs) — and one dataset partition
        assert_eq!(run.memo_misses, 1);
        assert_eq!(run.memo_hits, 1);
        assert_eq!(run.dataset_misses, 1);
        assert_eq!(run.dataset_hits, 1);
        // the report parses back and carries every cell
        let text = run.report_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("n_cells").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("cells").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn chaos_cells_share_one_environment_build() {
        let mut spec = CampaignSpec::smoke();
        spec.strategies = vec![StrategyKind::FedZero];
        spec.chaos_axis = vec![
            None,
            Some(ChaosSpec { dropout_per_round: 0.5, ..ChaosSpec::default() }),
        ];
        let run = run_campaign(&spec, 1).unwrap();
        assert_eq!(run.results.len(), 2);
        // chaos is a sim-time knob: both cells must hit one shared build
        assert_eq!(run.memo_misses, 1);
        assert_eq!(run.memo_hits, 1);
        assert_eq!(run.dataset_misses, 1);
        assert_eq!(run.dataset_hits, 1);
        for r in &run.results {
            assert!(r.rounds > 0, "{} did no rounds", r.cell.label);
        }
        // the chaos flag and robustness counters land in the report
        let parsed = Json::parse(&run.report_json().to_string_pretty()).unwrap();
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells[0].get("chaos").unwrap().as_bool(), Some(false));
        assert_eq!(cells[1].get("chaos").unwrap().as_bool(), Some(true));
        for c in cells {
            assert!(c.get("rejected_updates").unwrap().as_f64().is_some());
            assert!(c.get("timeout_rounds").unwrap().as_f64().is_some());
        }
    }

    fn scratch_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fedzero_campaign_{}_{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// The campaign-level recovery gate: a chaos campaign (crash_prob
    /// armed in the axis — stripped per cell, module docs) run durably,
    /// interrupted by losing/corrupting completion records, resumes
    /// over the same directory to a byte-identical report.
    #[test]
    fn durable_campaign_resumes_to_identical_report() {
        let mut spec = CampaignSpec::smoke();
        spec.chaos_axis = vec![
            None,
            Some(ChaosSpec {
                dropout_per_round: 0.3,
                stale_prob: 0.1,
                crash_prob: 1.0, // must be disarmed per cell, or nothing completes
                ..ChaosSpec::default()
            }),
        ];
        let reference = run_campaign(&spec, 1).unwrap().report_json().to_string_pretty();

        let dir = scratch_dir("resume");
        let full = run_campaign_durable(&spec, 1, &dir).unwrap();
        assert_eq!(
            full.report_json().to_string_pretty(),
            reference,
            "durable run diverged from the plain run"
        );
        let n = full.results.len();
        assert_eq!(n, 4);
        for i in 0..n {
            assert!(
                dir.join(format!("cells/cell_{i}.json")).is_file(),
                "cell {i} left no completion record"
            );
        }

        // interrupt: lose one record, corrupt a second, tamper a third's
        // fingerprint — all three must be recomputed, the fourth reloaded
        std::fs::remove_file(dir.join("cells/cell_0.json")).unwrap();
        std::fs::write(dir.join("cells/cell_1.json"), b"{ torn").unwrap();
        let path2 = dir.join("cells/cell_2.json");
        let tampered = std::fs::read_to_string(&path2)
            .unwrap()
            .replace("fedzero-campaign-cell-v1", "fedzero-campaign-cell-v0");
        std::fs::write(&path2, tampered).unwrap();

        for workers in [1usize, 2, 8] {
            let resumed = run_campaign_durable(&spec, workers, &dir).unwrap();
            assert_eq!(
                resumed.report_json().to_string_pretty(),
                reference,
                "resume at {workers} workers diverged"
            );
        }
        // the repaired records parse and match again: a final resume
        // reloads everything (zero cells run → zero memo traffic)
        let resumed = run_campaign_durable(&spec, 1, &dir).unwrap();
        assert_eq!(resumed.memo_misses + resumed.memo_hits, 0, "cells were re-run");
        assert_eq!(resumed.report_json().to_string_pretty(), reference);

        // a different grid refuses the records wholesale
        let mut other = spec.clone();
        other.seeds = vec![1];
        let other_run = run_campaign_durable(&other, 1, &dir).unwrap();
        assert_ne!(other_run.report_json().to_string_pretty(), reference);

        let _ = std::fs::remove_dir_all(&dir);
    }
}
