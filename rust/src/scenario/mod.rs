//! Declarative scenario engine + campaign runner.
//!
//! The paper evaluates two hard-coded environments (`config::Scenario::
//! {Global, Colocated}`), one experiment per process. This subsystem
//! generalises that into (1) a declarative [`EnvSpec`] parsed from JSON,
//! (2) a spec-driven environment builder ([`build_env`]) that reproduces
//! the legacy `config::build` **bit for bit** for the builtin specs, and
//! (3) a parallel campaign runner ([`campaign`]) that expands a spec
//! grid into scenario×strategy×seed cells, drains them across workers
//! with memoized trace generation, and writes one deterministic
//! machine-readable report. Every future sweep — robustness, fairness,
//! scale — builds on this layer; `fedzero repro campaign <spec.json>`
//! is the CLI entry point.
//!
//! ## EnvSpec JSON schema
//!
//! ```json
//! {
//!   "sites": "global" | "colocated" |
//!            [{"name": "Reykjavik", "latitude": 64.1,
//!              "utc_offset_h": 0.0, "cloudiness": 0.5}, ...],
//!   "start_day_of_year": 159,          // optional; preset default
//!   "regional_clouds": 0.4,            // optional; null = independent
//!   "capacity_w": 800 | [500, 1200],   // broadcast or per-domain, W
//!   "battery_wh": 0 | [400, 0],        // per-domain storage, Wh
//!   "battery_sustain_frac": 0.25,      // discharge floor, × capacity
//!   "device_mix": [0.7, 0.2, 0.1],     // [small, mid, large] weights
//!   "energy_error_params": {"sigma0": 0.2, "sigma_max": 0.35,
//!                           "bias": 0.02},
//!   "churn": {"outages_per_day": 1.5, "mean_outage_min": 45},
//!   "chaos": {"dropout_per_round": 0.1, "stale_prob": 0.05, ...}
//! }
//! ```
//!
//! Every field is optional; the empty object is the paper's global
//! scenario. `"chaos"` (schema in [`crate::sim::chaos`]) is the only
//! sim-time field: it injects round-scoped faults through the event
//! queue and never touches the environment build. See [`campaign`] for
//! the campaign schema that wraps this with sweep axes (site sets,
//! Dirichlet α grids, forecast-error regimes, batteries, churn, chaos,
//! strategies, seeds).
//!
//! ## Bit-equivalence contract
//!
//! [`build_env`] follows the exact RNG call sequence of the legacy
//! `config::build` (fork tags, draw order, float arithmetic) whenever
//! the spec's generalising knobs are at their builtin defaults; the new
//! knobs either consume no randomness (batteries, error-parameter
//! overrides) or draw from independent streams (churn), so enabling
//! them cannot perturb the base traces. `config::build` is retained as
//! the oracle and the equivalence is gated by tests below, by the
//! coordinator's `MetricsLog` equality test, and by
//! `benches/campaign.rs` in CI.
//!
//! ## Battery model
//!
//! A domain with `battery_wh > 0` routes its generated power trace
//! through [`crate::energy::battery::Battery`] ([`apply_battery`]):
//! power above `battery_sustain_frac × capacity` charges the battery
//! (losses applied), and steps below that threshold discharge it to
//! raise the floor — shifting day surplus into night availability, the
//! §7 storage extension the ablation bench quantifies. The transform is
//! applied before the forecaster is built, so the server forecasts the
//! battery-smoothed series, and it is deterministic (no RNG).

pub mod campaign;
pub mod churn;
pub mod spec;

pub use churn::ChurnSpec;
pub use spec::{EnvConfig, EnvSpec, ErrorParams, SiteSet};

use anyhow::{bail, Result};

use crate::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
use crate::config::BuiltScenario;
use crate::data::Partition;
use crate::energy::battery::Battery;
use crate::energy::PowerDomain;
use crate::trace::forecast::{ErrorLevel, SeriesForecaster};
use crate::trace::load::{plan_forecast, LoadModel};
use crate::trace::solar;
use crate::util::rng::Rng;

/// Route a power trace through a battery: steps above `sustain_w` charge
/// it with the surplus (the drawn energy leaves the trace), steps below
/// discharge toward the `sustain_w` floor. Physically honest — capacity,
/// C/2 power limits and round-trip losses all apply — and deterministic.
pub fn apply_battery(power_w: &mut [f64], step_minutes: f64, battery_wh: f64, sustain_w: f64) {
    if battery_wh <= 0.0 {
        return;
    }
    let mut battery = Battery::new(battery_wh);
    let step_h = step_minutes / 60.0;
    // Battery's max_charge/discharge fields are per-CALL energy caps;
    // one call here is one step, so scale the C/2 POWER limit
    // (battery_wh/2 W) to the step duration — without this a 1-minute
    // step would allow a ~30C charge rate
    battery.max_charge_wh = battery_wh / 2.0 * step_h;
    battery.max_discharge_wh = battery_wh / 2.0 * step_h;
    for p in power_w.iter_mut() {
        if *p > sustain_w {
            let drawn = battery.charge((*p - sustain_w) * step_h);
            *p -= drawn / step_h;
        } else if *p < sustain_w {
            let delivered = battery.discharge((sustain_w - *p) * step_h);
            *p += delivered / step_h;
        }
    }
}

/// Sample a device type from explicit mix weights (the generalised
/// alternative to the paper's uniform [`DeviceType::sample`]).
fn sample_device(rng: &mut Rng, mix: &[f64; 3]) -> DeviceType {
    let total: f64 = mix.iter().sum();
    let mut r = rng.f64() * total;
    for (k, &w) in mix.iter().enumerate() {
        r -= w;
        if r < 0.0 {
            return DeviceType::ALL[k];
        }
    }
    DeviceType::Large
}

/// Build one environment from a declarative spec — the generalisation of
/// the legacy `config::build` (see the module docs for the equivalence
/// contract). `partition` provides each client's data shard (and thereby
/// m_min/m_max); `model` picks the Table-2 column.
pub fn build_env(
    env: &EnvSpec,
    cfg: &EnvConfig,
    model: ModelKind,
    batch_size: usize,
    partition: &Partition,
) -> Result<BuiltScenario> {
    env.validate()?;
    if partition.clients.len() != cfg.n_clients {
        bail!(
            "partition has {} clients, spec wants {}",
            partition.clients.len(),
            cfg.n_clients
        );
    }
    let mut rng = Rng::new(cfg.seed ^ 0xC0FFEE);
    let horizon = (cfg.days as f64 * 24.0 * 60.0 / cfg.step_minutes) as usize;
    let sites = env.sites.sites();
    let n_domains = sites.len();
    let start_day = env.start_day();

    // --- power domains (same RNG sequence as the legacy builder) ----------
    let regional = env.regional_clouds.map(|cloudiness| {
        solar::regional_cloud_series(horizon, cfg.step_minutes, cloudiness, &mut rng.fork(0xC10D))
    });
    let mut domains: Vec<PowerDomain> = sites
        .iter()
        .enumerate()
        .map(|(i, site)| {
            let mut site_rng = rng.fork(0x50 + i as u64);
            let capacity_w = env.capacity_of(i);
            let mut power = solar::generate(
                site,
                capacity_w,
                start_day,
                horizon,
                cfg.step_minutes,
                &mut site_rng,
                regional.as_deref(),
            );
            // storage smoothing (no RNG — cannot perturb the sequence)
            apply_battery(
                &mut power,
                cfg.step_minutes,
                env.battery_of(i),
                env.battery_sustain_frac * capacity_w,
            );
            let mut forecaster = match cfg.energy_error {
                ErrorLevel::Perfect => SeriesForecaster::perfect(power.clone()),
                _ => SeriesForecaster::realistic(
                    power.clone(),
                    cfg.seed ^ (i as u64) << 8,
                    60.0 / cfg.step_minutes,
                ),
            };
            if let (ErrorLevel::Realistic, Some(p)) = (cfg.energy_error, env.energy_error_params) {
                forecaster.sigma0 = p.sigma0;
                forecaster.sigma_max = p.sigma_max;
                forecaster.bias = p.bias;
            }
            PowerDomain::new(i, &site.name, capacity_w, power, forecaster, cfg.step_minutes)
        })
        .collect();
    if let Some(u) = cfg.unlimited_domain {
        domains[u].unlimited = true;
    }

    // --- clients (same RNG sequence; the device-mix override swaps the
    // draw only when the spec departs from the paper's uniform mix) -------
    let mut clients = Vec::with_capacity(cfg.n_clients);
    let mut load_actual = Vec::with_capacity(cfg.n_clients);
    let mut load_fc = Vec::with_capacity(cfg.n_clients);
    for i in 0..cfg.n_clients {
        let domain = rng.below(n_domains);
        let device = match &env.device_mix {
            None => DeviceType::sample(&mut rng),
            Some(mix) => sample_device(&mut rng, mix),
        };
        let profile = ClientProfile::new(device, model, batch_size, cfg.step_minutes);
        let info = ClientInfo::new(
            i,
            domain,
            profile,
            partition.clients[i].clone(),
            batch_size,
        );

        let unlimited_client = cfg.unlimited_domain == Some(domain);
        let mut load_rng = rng.fork(0x10AD + i as u64);
        let util: Vec<f64> = if unlimited_client {
            vec![0.0; horizon]
        } else {
            LoadModel::sample(&mut load_rng, sites[domain].utc_offset_h)
                .generate(horizon, cfg.step_minutes, &mut load_rng)
        };
        let cap = info.capacity();
        let spare: Vec<f64> = util.iter().map(|&u| cap * (1.0 - u)).collect();
        let fc = match cfg.load_error {
            ErrorLevel::Perfect => SeriesForecaster::perfect(spare.clone()),
            _ => {
                let plan = plan_forecast(&spare, cfg.step_minutes);
                SeriesForecaster::perfect(plan)
            }
        };
        clients.push(info);
        load_actual.push(util);
        load_fc.push(fc);
    }

    // --- churn (independent RNG streams; see scenario::churn) -------------
    let outages = match &env.churn {
        Some(c) => c.generate(cfg.n_clients, horizon, cfg.step_minutes, cfg.seed),
        None => vec![Vec::new(); cfg.n_clients],
    };

    Ok(BuiltScenario { clients, domains, load_actual, load_fc, outages, horizon })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{build, Scenario, ScenarioConfig};
    use crate::data::partition::dirichlet_partition;

    fn quick_partition(n_clients: usize, rng: &mut Rng) -> Partition {
        let labels: Vec<i32> = (0..2000).map(|i| (i % 10) as i32).collect();
        dirichlet_partition(&labels, n_clients, 0.5, rng)
    }

    fn env_cfg(scenario_cfg: &ScenarioConfig) -> EnvConfig {
        EnvConfig {
            n_clients: scenario_cfg.n_clients,
            days: scenario_cfg.days,
            step_minutes: scenario_cfg.step_minutes,
            energy_error: scenario_cfg.energy_error,
            load_error: scenario_cfg.load_error,
            unlimited_domain: scenario_cfg.unlimited_domain,
            seed: scenario_cfg.seed,
        }
    }

    /// The tentpole acceptance gate: the builtin specs reproduce the
    /// legacy enum-driven builder bit for bit — traces, forecasters,
    /// client constants, everything the simulator consumes.
    #[test]
    fn builtin_specs_match_legacy_build_bitwise() {
        for (scenario, unlimited, seed) in [
            (Scenario::Global, None, 0u64),
            (Scenario::Global, Some(3), 7),
            (Scenario::Colocated, None, 42),
        ] {
            let mut rng = Rng::new(seed ^ 0x9A97);
            let part = quick_partition(30, &mut rng);
            let cfg = ScenarioConfig {
                scenario,
                n_clients: 30,
                days: 1,
                unlimited_domain: unlimited,
                seed,
                ..Default::default()
            };
            let legacy = build(&cfg, ModelKind::Vision, 10, &part);
            let spec = EnvSpec::builtin(scenario);
            let fresh =
                build_env(&spec, &env_cfg(&cfg), ModelKind::Vision, 10, &part).unwrap();

            assert_eq!(fresh.horizon, legacy.horizon);
            assert_eq!(fresh.client_domains(), legacy.client_domains());
            assert_eq!(fresh.domains.len(), legacy.domains.len());
            for (a, b) in fresh.domains.iter().zip(&legacy.domains) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.unlimited, b.unlimited);
                // bitwise: the f64 power series must be identical
                let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&a.power_w), bits(&b.power_w), "{scenario:?} {}", a.name);
                // forecaster draws the same realistic-error values
                for (t0, t) in [(0usize, 10usize), (5, 300), (100, 900)] {
                    assert_eq!(
                        a.forecaster.forecast(t0, t).to_bits(),
                        b.forecaster.forecast(t0, t).to_bits()
                    );
                }
            }
            for (a, b) in fresh.clients.iter().zip(&legacy.clients) {
                assert_eq!(a.domain, b.domain);
                assert_eq!(a.profile.device, b.profile.device);
                assert_eq!(a.m_min.to_bits(), b.m_min.to_bits());
                assert_eq!(a.m_max.to_bits(), b.m_max.to_bits());
            }
            for (a, b) in fresh.load_actual.iter().zip(&legacy.load_actual) {
                let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(a), bits(b));
            }
            for (a, b) in fresh.load_fc.iter().zip(&legacy.load_fc) {
                assert_eq!(a.forecast(0, 30).to_bits(), b.forecast(0, 30).to_bits());
            }
            assert!(fresh.outages.iter().all(|w| w.is_empty()));
        }
    }

    #[test]
    fn custom_sites_and_capacity_shape_the_domains() {
        let mut rng = Rng::new(1);
        let part = quick_partition(12, &mut rng);
        let spec = EnvSpec {
            sites: SiteSet::Custom(vec![
                solar::Site::new("Equator", 0.0, 0.0, 0.0),
                solar::Site::new("NearPole", 68.0, 0.0, 0.0),
            ]),
            capacity_w: vec![400.0, 1600.0],
            ..EnvSpec::global()
        };
        let cfg = EnvConfig { n_clients: 12, days: 1, ..Default::default() };
        let b = build_env(&spec, &cfg, ModelKind::Vision, 10, &part).unwrap();
        assert_eq!(b.domains.len(), 2);
        assert_eq!(b.domains[0].capacity_w, 400.0);
        assert_eq!(b.domains[1].capacity_w, 1600.0);
        let peak = |d: &PowerDomain| d.power_w.iter().cloned().fold(0.0f64, f64::max);
        // cloudless equatorial site at 4x the capacity out-peaks the
        // polar one despite the latter's longer summer day
        assert!(peak(&b.domains[1]) > peak(&b.domains[0]));
        assert!(peak(&b.domains[0]) > 100.0);
    }

    #[test]
    fn battery_shifts_surplus_into_dark_steps() {
        let mut series = vec![0.0; 120];
        for t in 0..60 {
            series[t] = 700.0; // bright morning
        }
        let original = series.clone();
        apply_battery(&mut series, 1.0, 300.0, 200.0);
        // energy is conserved minus round-trip losses and the charge
        // stranded when the window ends (no free energy, bounded loss)
        let sum = |v: &[f64]| v.iter().sum::<f64>() / 60.0; // Wh
        assert!(sum(&series) <= sum(&original) + 1e-9);
        assert!(sum(&series) >= sum(&original) * 0.7);
        // dark steps are lifted toward the sustain floor until the
        // battery drains (the C/2 power cap — 150 W here — binds first)
        assert!(series[60] > 100.0, "no discharge at step 60: {}", series[60]);
        assert!(series[60] <= 200.0 + 1e-9, "discharge overshot the floor");
        // bright steps gave up charge
        assert!(series[10] < 700.0);
        // the C/2 power limit binds per step: drawn ≤ 150 W equivalent
        assert!(original[10] - series[10] <= 150.0 + 1e-9);
        // with no battery the series is untouched
        let mut untouched = original.clone();
        apply_battery(&mut untouched, 1.0, 0.0, 200.0);
        assert_eq!(untouched, original);
    }

    #[test]
    fn device_mix_override_skews_the_fleet() {
        let mut rng = Rng::new(5);
        let part = quick_partition(60, &mut rng);
        let spec = EnvSpec { device_mix: Some([1.0, 0.0, 0.0]), ..EnvSpec::global() };
        let cfg = EnvConfig { n_clients: 60, days: 1, ..Default::default() };
        let b = build_env(&spec, &cfg, ModelKind::Vision, 10, &part).unwrap();
        assert!(b
            .clients
            .iter()
            .all(|c| c.profile.device == DeviceType::Small));
    }

    #[test]
    fn error_params_override_widens_forecast_error() {
        let mut rng = Rng::new(6);
        let part = quick_partition(10, &mut rng);
        let cfg = EnvConfig { n_clients: 10, days: 1, ..Default::default() };
        let base = build_env(&EnvSpec::global(), &cfg, ModelKind::Vision, 10, &part).unwrap();
        let spec = EnvSpec {
            energy_error_params: Some(ErrorParams { sigma0: 0.5, sigma_max: 0.9, bias: 0.3 }),
            ..EnvSpec::global()
        };
        let wide = build_env(&spec, &cfg, ModelKind::Vision, 10, &part).unwrap();
        // identical actual traces...
        assert_eq!(base.domains[0].power_w, wide.domains[0].power_w);
        // ...but the override propagated into the forecasters
        assert_eq!(wide.domains[0].forecaster.sigma0, 0.5);
        assert_eq!(wide.domains[0].forecaster.bias, 0.3);
        assert_eq!(base.domains[0].forecaster.sigma0, 0.10);
    }

    #[test]
    fn churn_spec_populates_outages() {
        let mut rng = Rng::new(8);
        let part = quick_partition(20, &mut rng);
        let spec = EnvSpec {
            churn: Some(ChurnSpec { outages_per_day: 6.0, mean_outage_min: 120.0 }),
            ..EnvSpec::global()
        };
        let cfg = EnvConfig { n_clients: 20, days: 2, ..Default::default() };
        let b = build_env(&spec, &cfg, ModelKind::Vision, 10, &part).unwrap();
        assert_eq!(b.outages.len(), 20);
        let events: usize = b.outages.iter().map(|w| w.len()).sum();
        assert!(events > 0, "churn spec produced no outages");
        // traces are untouched relative to the churn-free build
        let plain = build_env(&EnvSpec::global(), &cfg, ModelKind::Vision, 10, &part).unwrap();
        assert_eq!(b.domains[0].power_w, plain.domains[0].power_w);
        assert_eq!(b.load_actual, plain.load_actual);
    }
}
