//! The simulation engine.
//!
//! §Perf — the two hot structures of the simulation loop:
//!
//! * **Persistent forecast ring-arena + incremental selection state**
//!   ([`crate::selection::ring`], [`crate::selection::incr`]): the
//!   engine owns one [`ForecastRing`] and one [`IncrSelState`] across
//!   the whole run. After every executed round it re-anchors both
//!   (forecasts re-issued at round start, as the paper's server does);
//!   during consecutive idle (wait) polls it *advances* them by one slot
//!   — evict column t, append column t+d_max at the same issue anchor,
//!   patch the integer liveness counters and the per-domain/per-client
//!   reach structures of dirty domains. A FULLY DARK idle poll is
//!   **O(D)**: the σ refresh, the spare_now refresh, the ring's spare
//!   appends and the quick eligibility gate all skip per-client work
//!   (see the respective §Perf notes in the loop below). Strategies see
//!   the window as a borrowed [`FcView`] in the [`SelectionContext`];
//!   nothing is copied per select(). Under `ErrorLevel::Perfect` the
//!   anchoring is unobservable (forecast = actual regardless of issue
//!   time); under `Realistic` it means idle-period re-polls reuse the
//!   forecast issued at the start of the idle stretch rather than
//!   re-issuing every simulated minute — which matches how forecast
//!   vendors actually behave and is what makes the incremental advance
//!   byte-identical to a fresh build (see the ring docs).
//! * **Parallel round execution**: within one step, power attribution is
//!   independent across domains (a selected client belongs to exactly one
//!   domain), so `execute_round` computes every domain's water-filling
//!   grants in a fork-join (`util::par`, reused per-worker scratch) and
//!   then applies them — progress, energy metering, loss accounting —
//!   serially in ascending (domain, slot) order. The apply order and all
//!   f64 arithmetic are identical to the serial path, so metrics and
//!   model state are bit-identical whether or not the fan-out engages
//!   (`par_domains_min` + `par_slots_min` gate it on domain count AND
//!   work; tests force both paths and compare). The per-step
//!   `active`/`reqs`/grant buffers are hoisted out of the step loop and
//!   refilled in place on both paths.
//! * **Shard-parallel local training** (`fl` module docs): the backend is
//!   a `&self` read-mostly core, and each client's mutable train state
//!   (local params, data cursor, step counter) lives in an engine-owned
//!   [`ClientTrainState`]. Per step, the serial apply phase only
//!   *schedules* whole batches (one [`TrainJob`] per slot that earned
//!   them); the jobs — independent by construction, every job owns its
//!   client's state exclusively — then run through
//!   `TrainBackend::train_shard`, which `Sync` backends fan out across
//!   `util::par` workers. Job stats feed the loss accounting back in
//!   ascending slot order, so `MetricsLog`, the energy meter and the
//!   aggregated global model are bit-identical between the serial and
//!   sharded train paths (tests and the endtoend bench gate enforce
//!   this). Aggregation reads participant params straight out of the
//!   client states (no per-round model copies), and total train steps
//!   are a deterministic per-client reduction (`Simulation::steps_executed`)
//!   instead of a shared mutable counter.

use anyhow::Result;

use crate::client::ClientInfo;
use crate::energy::{attribute_power, EnergyMeter, PowerDomain, PowerRequest};
use crate::fl::{fedavg_weights, ClientTrainState, TrainBackend, TrainJob};
use crate::metrics::{EvalRecord, MetricsLog, RoundRecord};
use crate::selection::incr::IncrSelState;
use crate::selection::oort::UtilityTracker;
use crate::selection::ring::{FcSource, FcView, ForecastRing};
use crate::selection::{ClientRoundState, SelectionContext, SelectionDecision, Strategy};
use crate::trace::forecast::{ErrorLevel, SeriesForecaster};
use crate::util::par;
use crate::util::par::thresholds;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub step_minutes: f64,
    /// total simulated steps (paper: 7 days = 10080 one-minute steps)
    pub horizon: usize,
    /// clients selected per round (n)
    pub n_per_round: usize,
    /// max round duration in steps (d_max)
    pub d_max: usize,
    /// evaluate the global model every this many rounds
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            step_minutes: 1.0,
            horizon: 7 * 24 * 60,
            n_per_round: 10,
            d_max: 60,
            eval_every: 5,
            seed: 0,
        }
    }
}

/// Outcome of one executed round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub duration: usize,
    /// clients that reached m_min (their updates were aggregated)
    pub participants: Vec<usize>,
    /// clients whose work was discarded (selected, did not reach m_min)
    pub stragglers: Vec<usize>,
    pub total_batches: f64,
    pub energy_wh: f64,
    /// the stragglers' share of `energy_wh` — spent on discarded work
    pub wasted_wh: f64,
}

/// Everything needed to simulate one experiment configuration.
pub struct Simulation<'a, B: TrainBackend> {
    pub cfg: SimConfig,
    pub clients: Vec<ClientInfo>,
    pub domains: Vec<PowerDomain>,
    /// actual utilisation per client per step ([0,1]); spare capacity is
    /// m_c · (1 − util)
    pub load_actual: Vec<Vec<f64>>,
    /// spare-capacity forecasters per client (over the spare series, in
    /// batches/step); `ErrorLevel::Unavailable` means "assume full m_c"
    pub load_fc: Vec<SeriesForecaster>,
    pub load_fc_level: ErrorLevel,
    /// read-mostly backend core (`fl` module docs); all per-client
    /// mutation goes through `train_states`
    pub backend: &'a B,
    pub strategy: &'a mut dyn Strategy,
    /// fan the per-domain round-execution loop out across threads once a
    /// round spans at least this many domains AND selects at least
    /// `par_slots_min` clients — both gates, because thread spawn/join
    /// costs more than water-filling a handful of slots (identical
    /// results either way; tests pin these to 1 / usize::MAX to force
    /// both paths)
    pub par_domains_min: usize,
    /// minimum selected-client count before the per-domain fan-out
    /// engages (see `par_domains_min`)
    pub par_slots_min: usize,
    /// per-client outage windows `[start, end)` from the scenario churn
    /// model; empty (the default and the paper's setting) = every client
    /// always online. An offline client is excluded from the active set
    /// before power requests are built, so it receives no energy and no
    /// batches for the step. Selection stays churn-blind (the server
    /// cannot forecast outages); a client that drops mid-round stalls
    /// and, if it misses m_min, is discarded as a straggler.
    pub outages: Vec<Vec<(usize, usize)>>,
    // --- state ---
    pub states: Vec<ClientRoundState>,
    /// persistent per-client train state (local params, data cursor,
    /// step counter); `take`n by the slot during an executed round and
    /// returned before aggregation, so a `None` here would mean a client
    /// was selected into two concurrent rounds (impossible: rounds are
    /// sequential)
    pub train_states: Vec<Option<ClientTrainState<B::Cursor>>>,
    pub utility: UtilityTracker,
    pub meter: EnergyMeter,
    pub metrics: MetricsLog,
    pub rng: Rng,
    /// wall-clock spent inside strategy.select (overhead accounting)
    pub select_time: std::time::Duration,
    /// the global model after `run` finishes (equality fixture for the
    /// serial-vs-sharded train-path tests and the bench gate)
    pub final_global: Vec<f32>,
}

/// Actual spare capacity of client `i` at step `t` (batches/step) — free
/// function so the parallel round-execution closures can capture plain
/// slices instead of the whole (non-Sync) simulation.
fn spare_actual_raw(
    clients: &[ClientInfo],
    load_actual: &[Vec<f64>],
    i: usize,
    t: usize,
) -> f64 {
    let util = load_actual
        .get(i)
        .and_then(|v| v.get(t))
        .copied()
        .unwrap_or(1.0);
    clients[i].capacity() * (1.0 - util)
}

/// Is client `i` online at step `t` per its outage windows? Windows are
/// sorted, disjoint `[start, end)` ranges from the scenario churn model
/// (`crate::scenario::churn`); an empty outage table (the legacy paper
/// scenarios) means every client is always online — and, because the
/// check only ever REMOVES slots from the active set, leaves the float
/// sequence of every grant computation untouched.
fn online_at(outages: &[Vec<(usize, usize)>], i: usize, t: usize) -> bool {
    match outages.get(i) {
        None => true,
        Some(ws) => !ws.iter().any(|&(start, end)| start <= t && t < end),
    }
}

/// The engine's forecast source for the ring: domain energy through each
/// domain's forecaster, client spare through the load forecasters,
/// pre-clamped to capacity (`ErrorLevel::Unavailable` = assume full m_c).
struct EngineFcSource<'a> {
    domains: &'a [PowerDomain],
    clients: &'a [ClientInfo],
    load_fc: &'a [SeriesForecaster],
    level: ErrorLevel,
}

impl FcSource for EngineFcSource<'_> {
    fn n_domains(&self) -> usize {
        self.domains.len()
    }

    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    fn energy_at(&self, t0: usize, t: usize, p: usize) -> f64 {
        self.domains[p].forecast_energy_wh(t0, t)
    }

    fn spare_at(&self, t0: usize, t: usize, i: usize) -> f64 {
        let cap = self.clients[i].capacity();
        match self.level {
            ErrorLevel::Unavailable => cap,
            _ => self.load_fc[i].forecast(t0, t).clamp(0.0, cap),
        }
    }
}

/// One step of one domain's round execution, compute phase only (pure):
/// filter the still-active slots, build their power requests from the
/// *pre-step* progress snapshot, water-fill the domain's actual energy,
/// and emit `(slot, batch_steps)` grants. Domains never share slots, so
/// the snapshot equals the live value and parallel == serial, bit for
/// bit. The caller applies grants (progress/meter/training) serially.
#[allow(clippy::too_many_arguments)]
fn compute_domain_grants(
    clients: &[ClientInfo],
    domains: &[PowerDomain],
    load_actual: &[Vec<f64>],
    outages: &[Vec<(usize, usize)>],
    sel: &[usize],
    progress: &[f64],
    unconstrained: bool,
    dom: usize,
    slots: &[usize],
    tt: usize,
    active: &mut Vec<usize>,
    reqs: &mut Vec<PowerRequest>,
    out: &mut Vec<(usize, f64)>,
) {
    out.clear();
    active.clear();
    // an offline (churned-out) client is dropped BEFORE requests are
    // built, so it is granted neither energy nor batches this step —
    // on either the constrained or the unconstrained (Upper Bound) path
    active.extend(
        slots
            .iter()
            .copied()
            .filter(|&s| {
                progress[s] < clients[sel[s]].m_max - 1e-9
                    && online_at(outages, sel[s], tt)
            }),
    );
    if active.is_empty() {
        return;
    }
    if unconstrained {
        // Upper bound: full capacity, grid energy
        for &s in active.iter() {
            let c = &clients[sel[s]];
            out.push((s, c.capacity().min(c.m_max - progress[s])));
        }
        return;
    }
    reqs.clear();
    reqs.extend(active.iter().map(|&s| {
        let c = &clients[sel[s]];
        let delta = c.delta();
        let spare = spare_actual_raw(clients, load_actual, sel[s], tt);
        PowerRequest {
            need_min_wh: delta * (c.m_min - progress[s]).max(0.0),
            need_max_wh: delta * (c.m_max - progress[s]).max(0.0),
            usable_wh: delta * spare.min(c.m_max - progress[s]).max(0.0),
        }
    }));
    let available = domains[dom].energy_wh(tt);
    if available.is_infinite() {
        // unlimited domain: everyone gets their cap
        for (&s, r) in active.iter().zip(reqs.iter()) {
            out.push((s, r.usable_wh.min(r.need_max_wh) / clients[sel[s]].delta()));
        }
    } else {
        let alloc = attribute_power(available, reqs);
        out.extend(
            active
                .iter()
                .zip(&alloc)
                .map(|(&s, &wh)| (s, wh / clients[sel[s]].delta())),
        );
    }
}

impl<'a, B: TrainBackend> Simulation<'a, B> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: SimConfig,
        clients: Vec<ClientInfo>,
        domains: Vec<PowerDomain>,
        load_actual: Vec<Vec<f64>>,
        load_fc: Vec<SeriesForecaster>,
        load_fc_level: ErrorLevel,
        backend: &'a B,
        strategy: &'a mut dyn Strategy,
    ) -> Self {
        let n_clients = clients.len();
        let n_domains = domains.len();
        let seed = cfg.seed;
        let step_minutes = cfg.step_minutes;
        let train_states = (0..n_clients)
            .map(|i| Some(ClientTrainState::new(backend.make_cursor(i))))
            .collect();
        Simulation {
            cfg,
            clients,
            domains,
            load_actual,
            load_fc,
            load_fc_level,
            backend,
            strategy,
            par_domains_min: thresholds::ROUND_DOMAINS,
            par_slots_min: thresholds::ROUND_SLOTS,
            outages: Vec::new(),
            states: vec![ClientRoundState::default(); n_clients],
            train_states,
            utility: UtilityTracker::new(n_clients),
            meter: EnergyMeter::new(n_clients, n_domains),
            metrics: MetricsLog::new(step_minutes),
            rng: Rng::new(seed ^ 0x51D),
            select_time: std::time::Duration::ZERO,
            final_global: Vec::new(),
        }
    }

    /// Total train-step executions across all clients: a deterministic
    /// reduction over the per-client state counters in client-index
    /// order — no shared mutable counter to contend on (or for a backend
    /// to forget to maintain).
    pub fn steps_executed(&self) -> u64 {
        self.train_states
            .iter()
            .map(|st| st.as_ref().map_or(0, |s| s.steps))
            .sum()
    }

    /// actual spare capacity of client `i` at step `t` (batches/step)
    fn spare_actual(&self, i: usize, t: usize) -> f64 {
        spare_actual_raw(&self.clients, &self.load_actual, i, t)
    }

    /// Run the full simulation: returns the metrics log (also stored).
    pub fn run(&mut self) -> Result<()> {
        let mut global = self.backend.init_params(self.cfg.seed as i32)?;
        let mut t = 0usize;
        let mut round = 0usize;
        // §Perf: the forecast ring-arena AND the incremental selection
        // state persist across the whole run — see the module docs.
        // `last_was_wait` decides advance (same anchor, O(D) when dark)
        // vs rebuild (re-issue at t, O((C+D)·d_max)).
        let mut ring = ForecastRing::new();
        let mut incr = IncrSelState::new();
        let wants_fc = self.strategy.needs_forecasts();
        let wants_spare = self.strategy.needs_spare_now();
        let use_incr = wants_fc && self.strategy.uses_selection_state();
        let mut last_was_wait = false;
        let mut samples: Vec<usize> = Vec::with_capacity(self.clients.len());
        let mut spare_now: Vec<f64> = Vec::with_capacity(self.clients.len());
        while t < self.cfg.horizon {
            // §Perf: σ/participation/blocklist only mutate when a round
            // executes, and the utility refresh is a pure function of
            // them — consecutive idle polls skip the O(C) refresh
            // entirely (bit-identical: it would recompute the same σ).
            // This invariant is also what keeps the incremental state's
            // liveness snapshot valid across advances.
            if !last_was_wait {
                samples.clear();
                samples.extend(self.clients.iter().map(|c| c.num_samples()));
                self.utility.refresh(&mut self.states, &samples);
            }

            // §Perf: the window is only maintained for strategies that
            // read forecasts (FedZero, *-fc); Random/Oort/UpperBound
            // never pay for it. The incremental selection state rides
            // along only for strategies that consume it (FedZero).
            if wants_fc {
                let src = EngineFcSource {
                    domains: &self.domains,
                    clients: &self.clients,
                    load_fc: &self.load_fc,
                    level: self.load_fc_level,
                };
                if ring.is_built() && last_was_wait && t == ring.window_start() + 1 {
                    if use_incr {
                        incr.advance(&mut ring, &src);
                    } else {
                        ring.advance(&src);
                    }
                } else if !ring.is_built() || ring.window_start() != t {
                    ring.rebuild(&src, t, self.cfg.d_max);
                    if use_incr {
                        incr.rebuild(&self.clients, &self.states, ring.view());
                    }
                }
            }
            // §Perf: the O(C) current-spare refresh only runs for
            // strategies that read it (needs_spare_now) — FedZero's
            // filters are purely forecast-driven, so its dark idle polls
            // stay O(D)
            if wants_spare {
                spare_now.clear();
                spare_now
                    .extend((0..self.clients.len()).map(|i| self.spare_actual(i, t)));
            }
            let decision = {
                let ctx = SelectionContext {
                    now: t,
                    n: self.cfg.n_per_round,
                    d_max: self.cfg.d_max,
                    clients: &self.clients,
                    states: &self.states,
                    domains: &self.domains,
                    fc: if wants_fc { ring.view() } else { FcView::empty() },
                    incr: if use_incr && incr.is_built() { Some(&incr) } else { None },
                    spare_now: &spare_now,
                };
                let t0 = std::time::Instant::now();
                let d = self.strategy.select(&ctx, &mut self.rng);
                self.select_time += t0.elapsed();
                d
            };
            if decision.wait {
                last_was_wait = true;
                t += 1;
                continue;
            }
            last_was_wait = false;

            let (out, losses) = self.execute_round(&decision, t, &global)?;

            // aggregate participant updates (weights = sample counts),
            // reading the params straight out of the returned client
            // states — no per-round model copies
            let participants = out.participants.clone();
            if !participants.is_empty() {
                let weights = fedavg_weights(
                    &participants
                        .iter()
                        .map(|&c| self.clients[c].num_samples())
                        .collect::<Vec<_>>(),
                );
                let updates: Vec<&[f32]> = participants
                    .iter()
                    .map(|&c| {
                        self.train_states[c]
                            .as_ref()
                            .expect("round returned its states")
                            .params
                            .as_slice()
                    })
                    .collect();
                global = self.backend.aggregate(&updates, &weights)?;
            }

            // bookkeeping: utility, participation, blocklist
            for (&c, &loss) in participants.iter().zip(&losses) {
                self.states[c].participation += 1;
                self.utility.update(c, loss, self.clients[c].num_samples());
            }
            self.strategy.on_round_end(
                &participants,
                &mut self.states,
                &mut self.rng,
            );

            let mean_loss = if losses.is_empty() {
                0.0
            } else {
                losses.iter().sum::<f64>() / losses.len() as f64
            };
            self.metrics.rounds.push(RoundRecord {
                round,
                start_step: t,
                duration_steps: out.duration,
                selected: decision.clients.clone(),
                participants: participants.clone(),
                batches: out.total_batches,
                energy_wh: out.energy_wh,
                wasted_wh: out.wasted_wh,
                mean_loss,
            });

            t += out.duration.max(1);
            round += 1;

            if round % self.cfg.eval_every == 0 || t >= self.cfg.horizon {
                let (acc, loss) = self.backend.evaluate(&global)?;
                self.metrics.evals.push(EvalRecord {
                    round,
                    step: t,
                    accuracy: acc,
                    loss,
                    cumulative_kwh: self.meter.total_kwh(),
                });
            }
        }
        self.final_global = global;
        Ok(())
    }

    /// Execute one round starting at `t0`. Returns (outcome, participant
    /// mean losses aligned with outcome.participants); the participants'
    /// updated params stay in `self.train_states` for the caller to
    /// aggregate.
    fn execute_round(
        &mut self,
        decision: &SelectionDecision,
        t0: usize,
        global: &[f32],
    ) -> Result<(RoundOutcome, Vec<f64>)> {
        self.meter.begin_round();
        let sel = &decision.clients;
        let k = sel.len();
        // pull the selected clients' persistent train states for the
        // round; params reset to the global snapshot in place (reusing
        // their capacity — the historical code cloned `global` k times)
        let mut round_states: Vec<ClientTrainState<B::Cursor>> =
            Vec::with_capacity(k);
        for &c in sel.iter() {
            let mut st = self.train_states[c].take().unwrap_or_else(|| {
                panic!(
                    "SelectionDecision lists client {c} more than once \
                     (decisions must select distinct clients)"
                )
            });
            st.reset_params(global);
            round_states.push(st);
        }
        let mut progress = vec![0.0f64; k]; // fractional batch credit
        let mut executed = vec![0usize; k]; // whole batches run
        let mut n_new = vec![0usize; k]; // whole batches earned this step
        let mut loss_acc = vec![0.0f64; k];
        let mut loss_batches = vec![0usize; k];
        let mut slot_wh = vec![0.0f64; k]; // per-slot energy (waste split)
        // incremental end-condition: progress is monotone within a round,
        // so count each slot once when it first crosses m_min instead of
        // rescanning all k slots every step. Slots with m_min <= 0 count
        // from step one, exactly like the historical rescan did.
        let mut reached = vec![false; k];
        let mut done = 0usize;
        for s in 0..k {
            if 0.0 >= self.clients[sel[s]].m_min - 1e-9 {
                reached[s] = true;
                done += 1;
            }
        }
        // §Perf (ROADMAP "per-step job vec"): ONE index-based job arena
        // hoisted to round scope — jobs reference slot indices into
        // `round_states` instead of borrowing them, so the buffer is
        // refilled in place every step and training steps allocate
        // nothing in steady state
        let mut jobs: Vec<TrainJob> = Vec::with_capacity(k);
        let mut duration = 0usize;

        // group selected clients by domain once per round (ascending
        // domain order — the serial apply order)
        let mut by_domain: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (slot, &c) in sel.iter().enumerate() {
            by_domain
                .entry(self.clients[c].domain)
                .or_default()
                .push(slot);
        }
        let groups: Vec<(usize, Vec<usize>)> = by_domain.into_iter().collect();

        // §Perf: all per-step buffers hoisted out of the step loop —
        // serial steps are allocation-free in steady state (the historical
        // code rebuilt `active`/`reqs`/`batch_steps` per domain per step)
        let mut grants: Vec<Vec<(usize, f64)>> = vec![Vec::new(); groups.len()];
        let mut active: Vec<usize> = Vec::new();
        let mut reqs: Vec<PowerRequest> = Vec::new();

        let round_cap = decision.max_duration.max(1).min(self.cfg.d_max);
        for step in 0..round_cap {
            let tt = t0 + step;
            if tt >= self.cfg.horizon {
                break;
            }
            duration = step + 1;

            // compute phase: per-domain water-filling, parallel at scale.
            // The fan-out gates on BOTH domain count and selected-slot
            // count (thread spawn/join dwarfs a few slots' float work).
            // Both paths refill the hoisted `grants` rows in place, so
            // steady-state steps allocate nothing either way. Closures
            // capture plain slices only (the backend/strategy fields are
            // not Sync) and read the pre-step `progress` snapshot.
            {
                let clients = &self.clients;
                let domains = &self.domains;
                let load_actual = &self.load_actual;
                let outages: &[Vec<(usize, usize)>] = &self.outages;
                let progress_ro: &[f64] = &progress;
                let unconstrained = decision.unconstrained;
                let use_par = groups.len() >= self.par_domains_min
                    && k >= self.par_slots_min
                    && par::threads() > 1;
                if use_par {
                    let groups = &groups;
                    par::par_fill_rows_scratch(
                        &mut grants,
                        1,
                        0,
                        || (Vec::new(), Vec::new()),
                        |g,
                         row: &mut [Vec<(usize, f64)>],
                         (active, reqs): &mut (Vec<usize>, Vec<PowerRequest>)| {
                            compute_domain_grants(
                                clients, domains, load_actual, outages, sel,
                                progress_ro, unconstrained, groups[g].0,
                                &groups[g].1, tt, active, reqs, &mut row[0],
                            );
                        },
                    );
                } else {
                    for (g, (dom, slots)) in groups.iter().enumerate() {
                        compute_domain_grants(
                            clients, domains, load_actual, outages, sel,
                            progress_ro, unconstrained, *dom, slots, tt,
                            &mut active, &mut reqs, &mut grants[g],
                        );
                    }
                }
            }

            // apply/meter phase: serial, ascending (domain, slot) order —
            // the exact historical sequence for progress and energy
            // metering. Training is only *scheduled* here: the whole
            // batches each slot earned this step go into `n_new`.
            for v in n_new.iter_mut() {
                *v = 0;
            }
            for (g, (dom, _slots)) in groups.iter().enumerate() {
                for &(s, b) in &grants[g] {
                    if b <= 0.0 {
                        continue;
                    }
                    progress[s] += b;
                    let wh = b * self.clients[sel[s]].delta();
                    self.meter.record(sel[s], *dom, wh);
                    slot_wh[s] += wh;
                    let want = progress[s].floor() as usize;
                    if want > executed[s] {
                        n_new[s] = want - executed[s];
                        executed[s] = want;
                    }
                    if !reached[s]
                        && progress[s] >= self.clients[sel[s]].m_min - 1e-9
                    {
                        reached[s] = true;
                        done += 1;
                    }
                }
            }

            // train phase: one job per slot that earned whole batches,
            // in ascending slot order (the strictly-increasing-slot
            // contract of `train_shard`). Each job exclusively owns its
            // slot's state, so the backend may fan the jobs out across
            // workers — per-slot params/stats are bit-identical to the
            // serial order either way, and the loss accounting below
            // stays serial in slot order.
            jobs.clear();
            for s in 0..k {
                if n_new[s] > 0 {
                    jobs.push(TrainJob::new(sel[s], n_new[s], s));
                }
            }
            if !jobs.is_empty() {
                self.backend.train_shard(global, &mut jobs, &mut round_states)?;
            }
            for j in &jobs {
                loss_acc[j.slot] += j.stats.mean_loss * j.n_batches as f64;
                loss_batches[j.slot] += j.n_batches;
            }

            // end condition: n_required clients reached their minimum
            // (incremental `done` counter, see above)
            if done >= decision.n_required {
                break;
            }
        }

        let mut participants = Vec::new();
        let mut stragglers = Vec::new();
        let mut losses = Vec::new();
        let mut wasted_wh = 0.0f64;
        for s in 0..k {
            if reached[s] && executed[s] > 0 {
                participants.push(sel[s]);
                losses.push(if loss_batches[s] > 0 {
                    loss_acc[s] / loss_batches[s] as f64
                } else {
                    0.0
                });
            } else {
                stragglers.push(sel[s]);
                wasted_wh += slot_wh[s];
            }
        }
        let total_batches: f64 = progress.iter().sum();
        let energy_wh = self.meter.round_wh(self.meter.rounds() - 1);
        // return the states; participants' params are read by the caller
        // for aggregation before the next round resets them
        for (s, st) in round_states.into_iter().enumerate() {
            self.train_states[sel[s]] = Some(st);
        }
        Ok((
            RoundOutcome {
                duration,
                participants,
                stragglers,
                total_batches,
                energy_wh,
                wasted_wh,
            },
            losses,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{ClientProfile, DeviceType, ModelKind};
    use crate::fl::MockBackend;
    use crate::selection::baselines::{Baseline, UpperBound};
    use crate::selection::fedzero::{FedZero, SolverKind};

    fn build(
        n_clients: usize,
        n_domains: usize,
        power_w: f64,
        horizon: usize,
    ) -> (Vec<ClientInfo>, Vec<PowerDomain>, Vec<Vec<f64>>, Vec<SeriesForecaster>)
    {
        let clients: Vec<ClientInfo> = (0..n_clients)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::ALL[i % 3],
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                ClientInfo::new(i, i % n_domains, p, (0..60).collect(), 10)
            })
            .collect();
        let domains: Vec<PowerDomain> = (0..n_domains)
            .map(|i| {
                let series = vec![power_w; horizon];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let load: Vec<Vec<f64>> =
            (0..n_clients).map(|_| vec![0.0; horizon]).collect();
        let load_fc: Vec<SeriesForecaster> = clients
            .iter()
            .map(|c| {
                SeriesForecaster::perfect(vec![c.capacity(); horizon])
            })
            .collect();
        (clients, domains, load, load_fc)
    }

    fn run_sim(
        strategy: &mut dyn Strategy,
        power_w: f64,
    ) -> (MetricsLog, f64) {
        let (m, kwh, _, _) = run_sim_forced(strategy, power_w, 8, usize::MAX);
        (m, kwh)
    }

    /// Run the fixture with both fan-outs pinned: `par_domains_min`
    /// forces/disables the grant compute fan-out, `par_train_min` the
    /// backend train-shard fan-out. Returns (metrics, kwh, final global
    /// params, total train steps).
    fn run_sim_forced(
        strategy: &mut dyn Strategy,
        power_w: f64,
        par_domains_min: usize,
        par_train_min: usize,
    ) -> (MetricsLog, f64, Vec<f32>, u64) {
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, power_w, horizon);
        let mut backend = MockBackend::new(9, 8, 0.2, 7);
        backend.par_min_jobs = par_train_min;
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            strategy,
        );
        sim.par_domains_min = par_domains_min;
        sim.par_slots_min = par_domains_min; // force both gates together
        sim.run().unwrap();
        let kwh = sim.meter.total_kwh();
        let steps = sim.steps_executed();
        let global = std::mem::take(&mut sim.final_global);
        (sim.metrics, kwh, global, steps)
    }

    #[test]
    fn fedzero_trains_and_converges_on_mock() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, kwh) = run_sim(&mut fz, 800.0);
        assert!(m.rounds.len() > 5, "only {} rounds", m.rounds.len());
        assert!(m.best_accuracy() > 0.5, "acc {}", m.best_accuracy());
        assert!(kwh > 0.0);
        // energy accounting consistent between meter and metrics
        assert!((kwh - m.total_energy_kwh()).abs() < 1e-9);
    }

    #[test]
    fn all_baselines_run() {
        for mut s in [
            Baseline::random(),
            Baseline::random_over(),
            Baseline::random_fc(),
            Baseline::oort(),
            Baseline::oort_over(),
            Baseline::oort_fc(),
        ] {
            let (m, _) = run_sim(&mut s, 800.0);
            assert!(!m.rounds.is_empty(), "{} did no rounds", s.name());
        }
        let mut ub = UpperBound;
        let (m, _) = run_sim(&mut ub, 0.0); // no excess energy needed
        assert!(m.best_accuracy() > 0.5);
    }

    #[test]
    fn no_power_means_no_rounds_except_upper_bound() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, kwh) = run_sim(&mut fz, 0.0);
        assert!(m.rounds.is_empty());
        assert_eq!(kwh, 0.0);
    }

    #[test]
    fn energy_budget_is_respected_per_domain_step() {
        // run with modest power and verify no round used more energy than
        // domains could provide: total kWh <= power * time
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, kwh) = run_sim(&mut fz, 100.0);
        let horizon_h = 600.0 / 60.0;
        let max_possible_kwh = 3.0 * 100.0 * horizon_h / 1000.0;
        assert!(kwh <= max_possible_kwh + 1e-9, "{kwh} > {max_possible_kwh}");
        assert!(!m.rounds.is_empty());
    }

    #[test]
    fn over_selection_discards_stragglers() {
        // scarce energy -> with 1.3n over-selection some clients won't
        // finish; participants <= selected
        let mut s = Baseline::random_over();
        let (m, _) = run_sim(&mut s, 60.0);
        let mut saw_discard = false;
        for r in &m.rounds {
            assert!(r.participants.len() <= r.selected.len());
            if r.participants.len() < r.selected.len() {
                saw_discard = true;
            }
            // waste accounting: the stragglers' energy is a sub-share of
            // the round total, and zero when everyone finished
            assert!(r.wasted_wh >= 0.0 && r.wasted_wh <= r.energy_wh + 1e-9);
            if r.participants.len() == r.selected.len() {
                assert_eq!(r.wasted_wh, 0.0);
            }
        }
        assert!(saw_discard, "expected at least one straggler");
        assert!(m.total_wasted_kwh() > 0.0, "stragglers wasted no energy?");
    }

    #[test]
    fn offline_clients_get_no_energy_and_no_batches() {
        // the churn-model contract: a client inside an outage window is
        // granted neither energy nor training batches — here client 0 is
        // offline for the whole horizon, so it must end at exactly zero
        // despite abundant power and being selectable
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, 800.0, horizon);
        let backend = MockBackend::new(9, 8, 0.2, 7);
        let mut s = Baseline::random();
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            &mut s,
        );
        let mut outages = vec![Vec::new(); 9];
        outages[0] = vec![(0, horizon)];
        outages[1] = vec![(0, 100), (300, 400)]; // partial outages
        sim.outages = outages;
        sim.run().unwrap();
        assert!(!sim.metrics.rounds.is_empty());
        assert_eq!(sim.meter.client_wh(0), 0.0, "offline client drew energy");
        assert_eq!(
            sim.train_states[0].as_ref().unwrap().steps,
            0,
            "offline client ran batches"
        );
        assert_eq!(sim.metrics.participation_counts(9)[0], 0);
        // the partially offline client can still participate while online
        // but never inside its windows: rounds fully inside an outage
        // window must not list it as a participant
        for r in &sim.metrics.rounds {
            let span = (r.start_step, r.start_step + r.duration_steps);
            let inside_outage =
                span.1 <= 100 || (span.0 >= 300 && span.1 <= 400);
            if inside_outage {
                assert!(
                    !r.participants.contains(&1),
                    "client 1 participated during an outage (round at {span:?})"
                );
            }
        }
        // the run as a whole still makes progress
        assert!(sim.meter.total_kwh() > 0.0);
    }

    #[test]
    fn empty_outage_table_changes_nothing() {
        // the churn hook must be a strict no-op when unused: a run with
        // an explicit all-online table equals the default bit for bit
        let mut a = FedZero::new(SolverKind::Greedy);
        let (m_default, kwh_default) = run_sim(&mut a, 300.0);
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, 300.0, horizon);
        let mut backend = MockBackend::new(9, 8, 0.2, 7);
        backend.par_min_jobs = usize::MAX; // mirror run_sim's fixture
        let mut fz = FedZero::new(SolverKind::Greedy);
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            &mut fz,
        );
        sim.outages = vec![Vec::new(); 9]; // explicit, but all online
        sim.par_domains_min = 8; // mirror run_sim's forced gates
        sim.par_slots_min = 8;
        sim.run().unwrap();
        assert_eq!(sim.metrics, m_default);
        assert_eq!(sim.meter.total_kwh(), kwh_default);
    }

    #[test]
    fn fedzero_rounds_do_not_exceed_dmax() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, _) = run_sim(&mut fz, 300.0);
        for r in &m.rounds {
            assert!(r.duration_steps <= 30);
        }
    }

    #[test]
    fn participation_is_tracked() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, _) = run_sim(&mut fz, 800.0);
        let counts = m.participation_counts(9);
        assert_eq!(
            counts.iter().sum::<usize>(),
            m.rounds.iter().map(|r| r.participants.len()).sum::<usize>()
        );
    }

    #[test]
    fn parallel_round_execution_matches_serial_bitwise() {
        // same sim, forced-parallel vs forced-serial domain execution:
        // every metric (incl. f64 energy/loss values) must be identical.
        // On single-core hosts both runs take the serial path and the
        // assertion is trivially true.
        for power in [800.0, 100.0, 60.0] {
            let mut fz_par = FedZero::new(SolverKind::Greedy);
            let (m_par, kwh_par, _, _) =
                run_sim_forced(&mut fz_par, power, 1, usize::MAX);
            let mut fz_ser = FedZero::new(SolverKind::Greedy);
            let (m_ser, kwh_ser, _, _) =
                run_sim_forced(&mut fz_ser, power, usize::MAX, usize::MAX);
            assert_eq!(m_par, m_ser, "metrics diverged at power {power}");
            assert_eq!(kwh_par, kwh_ser, "energy diverged at power {power}");
        }
        // over-selection exercises straggler paths under contention
        let mut b_par = Baseline::random_over();
        let (m_par, _, _, _) = run_sim_forced(&mut b_par, 60.0, 1, usize::MAX);
        let mut b_ser = Baseline::random_over();
        let (m_ser, _, _, _) =
            run_sim_forced(&mut b_ser, 60.0, usize::MAX, usize::MAX);
        assert_eq!(m_par, m_ser);
    }

    #[test]
    fn parallel_training_matches_serial_bitwise() {
        // forced shard fan-out vs forced serial shard, with the grant
        // fan-out toggled independently: MetricsLog, energy, the FINAL
        // GLOBAL MODEL (bitwise) and the step totals must all agree.
        for power in [800.0, 100.0, 60.0] {
            let mut fz_ser = FedZero::new(SolverKind::Greedy);
            let (m_ser, kwh_ser, g_ser, steps_ser) =
                run_sim_forced(&mut fz_ser, power, usize::MAX, usize::MAX);
            for grants_min in [1usize, usize::MAX] {
                let mut fz_par = FedZero::new(SolverKind::Greedy);
                let (m_par, kwh_par, g_par, steps_par) =
                    run_sim_forced(&mut fz_par, power, grants_min, 1);
                assert_eq!(m_par, m_ser, "metrics diverged at power {power}");
                assert_eq!(kwh_par, kwh_ser, "energy diverged at power {power}");
                assert_eq!(steps_par, steps_ser, "steps diverged at {power}");
                let bits_ser: Vec<u32> =
                    g_ser.iter().map(|x| x.to_bits()).collect();
                let bits_par: Vec<u32> =
                    g_par.iter().map(|x| x.to_bits()).collect();
                assert_eq!(
                    bits_par, bits_ser,
                    "global model diverged at power {power}"
                );
            }
        }
        // straggler-heavy contention through the sharded path too
        let mut b_ser = Baseline::random_over();
        let (m_ser, _, g_ser, _) =
            run_sim_forced(&mut b_ser, 60.0, usize::MAX, usize::MAX);
        let mut b_par = Baseline::random_over();
        let (m_par, _, g_par, _) = run_sim_forced(&mut b_par, 60.0, 1, 1);
        assert_eq!(m_par, m_ser);
        assert_eq!(
            g_par.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            g_ser.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn steps_executed_counts_trained_batches() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, _, _, steps) = run_sim_forced(&mut fz, 800.0, 8, usize::MAX);
        assert!(!m.rounds.is_empty());
        // every executed whole batch is one train step; batch totals in
        // the metrics are fractional credits, so steps <= ceil(batches)
        let credit: f64 = m.rounds.iter().map(|r| r.batches).sum();
        assert!(steps > 0, "no steps recorded");
        assert!(
            (steps as f64) <= credit + m.rounds.len() as f64,
            "steps {steps} exceed batch credit {credit}"
        );
    }
}
